//! [`CopyProgram`]: a (src plan, dst plan) pair compiled **once** into
//! an executable copy schedule (EXPERIMENTS.md §Copy).
//!
//! `aosoa_copy` re-derives the chunk intersections of the two layouts
//! on every call; the program compiler runs that derivation once and
//! materializes the result as an ordered op list, so repeated copies
//! between the same layout pair — the common case in double-buffered
//! steps, frame reshuffles and serialization — replay precomputed
//! spans with zero mapping calls:
//!
//! * [`CopyOp::Memcpy`] — a raw byte span, emitted by the chunked
//!   strategy with **adjacent-span coalescing**: runs that follow each
//!   other in both layouts (across leaves and lane blocks) merge into
//!   one span. Blobwise-identical layouts compile to exactly one
//!   `Memcpy` per blob; AoSoA-N ↔ AoSoA-M pairs to gcd-sized runs.
//! * [`CopyOp::StridedRun`] — affine ↔ affine leaves with mismatched
//!   strides (e.g. aligned AoS ↔ SoA, previously field-wise): one op
//!   per leaf replaces per-record mapping calls. Executed through
//!   [`crate::view::simd::strided_run`]: scalar word moves by default,
//!   AVX2 gathers for 4/8-byte elements on the detected (or pinned,
//!   [`CopyProgram::execute_with_path`]) SIMD path — the op list itself
//!   never depends on the path.
//! * [`CopyOp::SwapRun`] — affine ↔ affine leaves with *mismatched*
//!   byte representation (exactly one side byteswapped): a strided run
//!   that writes each element's bytes reversed — the closed form behind
//!   `copy::wire`'s cross-endian pack/unpack. 1-byte leaves degrade to
//!   verbatim runs (reversal is the identity).
//! * [`CopyOp::Gather`] — element fallback when either side is generic
//!   (including representation conversion outside the affine closed
//!   form); resolves through the mappings at execution time,
//!   bit-identical to [`super::copy_naive`].
//!
//! Strategy selection (also what [`super::copy`] reports). "Equal
//! representation" means both sides native *or* both byteswapped —
//! equal-representation bytes move verbatim:
//!
//! | Pair | Strategy | [`super::CopyMethod`] |
//! |---|---|---|
//! | identical layouts | per-blob memcpy | `Blobwise` |
//! | equal repr + chunkable | span-merged chunk runs | `AoSoAChunked` |
//! | equal repr + affine | strided runs | `Program` |
//! | mismatched repr + affine | per-leaf swap runs | `SwapProgram` |
//! | otherwise | gather | `FieldWise` |
//!
//! The chunked strategy caps run lengths at **both** plans'
//! [`LayoutPlan::chunk_lanes`] — for Split mappings that is the gcd of
//! the children's lane counts (`LayoutPlan::compose_split`), never the
//! composed piecewise lane count, which can exceed a child's actual
//! run length (e.g. Split(AoSoA4, packed AoS) addresses piecewise at 4
//! lanes but only 1-element runs are contiguous on the AoS child).
//!
//! For parallel execution, [`shard_programs`] splits the record range
//! on [`crate::view::shard::pair_align`] boundaries (the lcm of both
//! plans' lane alignments) and compiles one sub-program per shard;
//! [`execute_parallel`] fans the sub-programs out over scoped threads.
//! Aliasing destination plans (`One`) collapse to a single program.

use crate::blob::{Blob, BlobMut};
use crate::mapping::{LayoutPlan, Mapping};
use crate::view::shard::shard_pair;
use crate::view::simd::{detect, SimdPath};
use crate::view::View;

use super::{
    layouts_identical_with, plans_chunk_compatible, plans_strided_compatible,
    plans_swap_compatible, ChunkOrder, CopyMethod,
};

/// One instruction of a compiled [`CopyProgram`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyOp {
    /// `dst[dst_blob][dst_off..dst_off+len] =
    /// src[src_blob][src_off..src_off+len]`.
    Memcpy {
        src_blob: usize,
        src_off: usize,
        dst_blob: usize,
        dst_off: usize,
        len: usize,
    },
    /// `count` elements of `elem` bytes each, at (possibly) different
    /// strides on the two sides.
    StridedRun {
        src_blob: usize,
        src_off: usize,
        src_stride: usize,
        dst_blob: usize,
        dst_off: usize,
        dst_stride: usize,
        elem: usize,
        count: usize,
    },
    /// Like [`CopyOp::StridedRun`], but each element's bytes are
    /// written in **reversed** order — the closed form of a native ↔
    /// byteswapped affine pair (`elem` ≥ 2; 1-byte elements compile to
    /// verbatim runs since reversal is the identity).
    SwapRun {
        src_blob: usize,
        src_off: usize,
        src_stride: usize,
        dst_blob: usize,
        dst_off: usize,
        dst_stride: usize,
        elem: usize,
        count: usize,
    },
    /// Field-wise element copy of `len` records, resolved through the
    /// mapping objects at execution time (handles generic addressing
    /// and byte-representation conversion). Source record
    /// `src_start + i` lands at destination record `dst_start + i` —
    /// whole-view programs have `src_start == dst_start`, slice
    /// programs ([`CopyProgram::compile_slice`]) may not.
    Gather { src_start: usize, dst_start: usize, len: usize },
}

/// A compiled copy schedule between two fixed layouts. Whole-view
/// programs ([`CopyProgram::compile`]) require the same data space on
/// both sides; slice programs ([`CopyProgram::compile_slice`]) only the
/// same record dimension, so `count` (source records) and `dst_count`
/// (destination records) can differ. Compile once per (src mapping,
/// dst mapping) pair, execute on any number of view pairs using those
/// mappings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CopyProgram {
    count: usize,
    dst_count: usize,
    method: CopyMethod,
    ops: Vec<CopyOp>,
}

/// Appends ops, merging a new `Memcpy` into the previous one when both
/// its source and destination continue the previous span's bytes.
struct OpSink {
    ops: Vec<CopyOp>,
}

impl OpSink {
    fn new() -> Self {
        OpSink { ops: Vec::new() }
    }

    fn memcpy(&mut self, sb: usize, so: usize, db: usize, doff: usize, n: usize) {
        if n == 0 {
            return;
        }
        if let Some(CopyOp::Memcpy { src_blob, src_off, dst_blob, dst_off, len }) =
            self.ops.last_mut()
        {
            if *src_blob == sb
                && *dst_blob == db
                && *src_off + *len == so
                && *dst_off + *len == doff
            {
                *len += n;
                return;
            }
        }
        self.ops.push(CopyOp::Memcpy {
            src_blob: sb,
            src_off: so,
            dst_blob: db,
            dst_off: doff,
            len: n,
        });
    }
}

impl CopyProgram {
    /// Compile the (src, dst) mapping pair, read-contiguous chunk
    /// traversal. Panics if the mappings do not share a data space.
    ///
    /// ```
    /// use llama::prelude::*;
    ///
    /// let d = llama::record_dim! { x: f32, y: f32 };
    /// let dims = ArrayDims::linear(256);
    /// let src = SoA::multi_blob(&d, dims.clone());
    /// let dst = AoSoA::new(&d, dims.clone(), 16);
    ///
    /// // Compile once...
    /// let prog = CopyProgram::compile(&src, &dst);
    /// assert_eq!(prog.method(), CopyMethod::AoSoAChunked);
    /// assert!(prog.is_closed_form()); // pure byte moves, no mapping calls
    ///
    /// // ...replay on any number of view pairs using those mappings.
    /// let mut a = alloc_view(src);
    /// a.set::<f32>(123, 1, 4.5);
    /// let mut b = alloc_view(dst);
    /// prog.execute(&a, &mut b);
    /// assert_eq!(b.get::<f32>(123, 1), 4.5);
    /// ```
    pub fn compile<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(src: &MS, dst: &MD) -> CopyProgram {
        Self::compile_ordered(src, dst, ChunkOrder::ReadContiguous)
    }

    /// [`CopyProgram::compile`] with an explicit chunk traversal order
    /// (affects op order of the chunked strategy — the paper's (r)/(w)
    /// distinction — never the copied bytes).
    pub fn compile_ordered<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
        src: &MS,
        dst: &MD,
        order: ChunkOrder,
    ) -> CopyProgram {
        let sp = src.plan();
        let dp = dst.plan();
        compile_with(src, dst, &sp, &dp, order)
    }

    /// Compile a **slice** program: source records
    /// `src_start .. src_start + len` land at destination records
    /// `dst_start .. dst_start + len`. Unlike [`CopyProgram::compile`],
    /// the two sides need not share array extents — only the record
    /// dimension — which is what range-restricted serialization
    /// (`copy::wire`) and halo exchanges need: a sub-range of a big
    /// view packed into (or unpacked from) a dense buffer of exactly
    /// `len` records at a different base index.
    ///
    /// Strategy selection matches the range compiler: chunk-compatible
    /// pairs walk lane runs at each side's own offset, affine pairs
    /// compile one strided (or swap) run per leaf, and only pairs with
    /// a generic side fall back to the element [`CopyOp::Gather`] —
    /// offsets never force a gather on their own, so lane-unaligned
    /// slab boundaries stay on closed-form runs for affine layouts.
    ///
    /// Panics if the record dimensions differ or either range is out of
    /// bounds.
    pub fn compile_slice<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
        src: &MS,
        dst: &MD,
        src_start: usize,
        dst_start: usize,
        len: usize,
    ) -> CopyProgram {
        let sp = src.plan();
        let dp = dst.plan();
        compile_slice_with(src, dst, &sp, &dp, src_start, dst_start, len)
    }

    /// Split the record range `begin..end` into consecutive chunks of
    /// at most `target` records whose interior boundaries fall on
    /// multiples of `align` records past `begin` — the tiling a
    /// streaming serializer executes one [`CopyProgram::compile_slice`]
    /// at a time (see `copy::wire::write_range_chunked`). Keeping every
    /// cut lane-block-aligned (pass [`crate::view::shard::shard_align`]
    /// of the source plan) means no chunk straddles an AoSoA lane block
    /// mid-lane, so per-chunk programs stay on the closed-form
    /// strategies the whole-range program would use. The chunks tile
    /// the range exactly: disjoint, in order, covering every record.
    pub fn chunk_slices(
        begin: usize,
        end: usize,
        target: usize,
        align: usize,
    ) -> Vec<(usize, usize)> {
        let align = align.max(1);
        // Round the target down to a whole number of align blocks; a
        // target below the alignment still advances one block at a
        // time (a cut inside a block would be worse than a big chunk).
        let stride = (target.max(1) / align).max(1) * align;
        let mut out = Vec::new();
        let mut b = begin;
        while b < end {
            let e = (b + stride).min(end);
            out.push((b, e));
            b = e;
        }
        out
    }

    /// Which strategy the compiler chose (what [`super::copy`] reports).
    #[inline]
    pub fn method(&self) -> CopyMethod {
        self.method
    }

    /// The compiled op list, in execution order.
    #[inline]
    pub fn ops(&self) -> &[CopyOp] {
        &self.ops
    }

    /// Source record count the program was compiled for.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Destination record count the program was compiled for (equal to
    /// [`CopyProgram::count`] except for slice programs).
    #[inline]
    pub fn dst_count(&self) -> usize {
        self.dst_count
    }

    /// True if no op needs the mapping objects at execution time
    /// (everything resolved to raw byte moves at compile time).
    pub fn is_closed_form(&self) -> bool {
        !self.ops.iter().any(|op| matches!(op, CopyOp::Gather { .. }))
    }

    /// Execute the program: replay the compiled byte moves from `src`'s
    /// blobs into `dst`'s. The views must use the mappings the program
    /// was compiled from (asserted structurally where cheap; a program
    /// executed on foreign views of the same shapes copies garbage but
    /// stays memory-safe — every access is bounds-checked).
    pub fn execute<MS, MD, BS, BD>(&self, src: &View<MS, BS>, dst: &mut View<MD, BD>)
    where
        MS: Mapping,
        MD: Mapping,
        BS: Blob,
        BD: BlobMut,
    {
        self.execute_with_path(src, dst, detect());
    }

    /// [`CopyProgram::execute`] on an explicit [`SimdPath`] (benchmark
    /// rows pin the path; [`CopyProgram::execute`] uses the detected
    /// one). Only [`CopyOp::StridedRun`] execution is affected — the
    /// copied bytes are identical on every path. Safe for any `path`
    /// value: unusable paths fall back to scalar word moves.
    pub fn execute_with_path<MS, MD, BS, BD>(
        &self,
        src: &View<MS, BS>,
        dst: &mut View<MD, BD>,
        path: SimdPath,
    ) where
        MS: Mapping,
        MD: Mapping,
        BS: Blob,
        BD: BlobMut,
    {
        let path = if path.is_vector() { path } else { SimdPath::Scalar };
        assert_eq!(self.count, src.count(), "program compiled for a different extent");
        assert_eq!(self.dst_count, dst.count(), "program compiled for a different extent");
        let info = src.mapping().info().clone();
        for op in &self.ops {
            match *op {
                CopyOp::Memcpy { src_blob, src_off, dst_blob, dst_off, len } => {
                    let (_, dblobs) = dst.mapping_and_blobs_mut();
                    dblobs[dst_blob].as_bytes_mut()[dst_off..dst_off + len].copy_from_slice(
                        &src.blobs()[src_blob].as_bytes()[src_off..src_off + len],
                    );
                }
                CopyOp::StridedRun {
                    src_blob,
                    src_off,
                    src_stride,
                    dst_blob,
                    dst_off,
                    dst_stride,
                    elem,
                    count,
                } => {
                    let (_, dblobs) = dst.mapping_and_blobs_mut();
                    crate::view::simd::strided_run(
                        path,
                        src.blobs()[src_blob].as_bytes(),
                        src_off,
                        src_stride,
                        dblobs[dst_blob].as_bytes_mut(),
                        dst_off,
                        dst_stride,
                        elem,
                        count,
                    );
                }
                CopyOp::SwapRun {
                    src_blob,
                    src_off,
                    src_stride,
                    dst_blob,
                    dst_off,
                    dst_stride,
                    elem,
                    count,
                } => {
                    let (_, dblobs) = dst.mapping_and_blobs_mut();
                    swap_run(
                        src.blobs()[src_blob].as_bytes(),
                        src_off,
                        src_stride,
                        dblobs[dst_blob].as_bytes_mut(),
                        dst_off,
                        dst_stride,
                        elem,
                        count,
                    );
                }
                CopyOp::Gather { src_start, dst_start, len } => {
                    for i in 0..len {
                        for leaf in 0..info.leaf_count() {
                            let size = info.fields[leaf].size();
                            super::naive::copy_field_between(
                                src,
                                dst,
                                leaf,
                                src_start + i,
                                dst_start + i,
                                size,
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Scalar kernel of [`CopyOp::SwapRun`]: move `count` elements of
/// `elem` bytes, writing each element's bytes in reversed order — the
/// representation conversion between a native and a byteswapped side.
#[allow(clippy::too_many_arguments)]
fn swap_run(
    sbytes: &[u8],
    src_off: usize,
    src_stride: usize,
    dbytes: &mut [u8],
    dst_off: usize,
    dst_stride: usize,
    elem: usize,
    count: usize,
) {
    for i in 0..count {
        let s = &sbytes[src_off + i * src_stride..src_off + i * src_stride + elem];
        let d = &mut dbytes[dst_off + i * dst_stride..dst_off + i * dst_stride + elem];
        for b in 0..elem {
            d[b] = s[elem - 1 - b];
        }
    }
}

/// [`CopyProgram::compile_ordered`] over plans the caller already
/// compiled (the dispatcher compiles each side exactly once per copy).
pub(crate) fn compile_with<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
) -> CopyProgram {
    assert!(
        super::same_data_space(src, dst),
        "copy program between different data spaces: {} vs {}",
        src.mapping_name(),
        dst.mapping_name()
    );
    if layouts_identical_with(src, dst, sp, dp) {
        // One memcpy per blob — padding and tail blocks included, which
        // is exactly what makes the identical case a pure memcpy.
        let mut ops = Vec::with_capacity(src.blob_count());
        for nr in 0..src.blob_count() {
            let len = src.blob_size(nr);
            if len > 0 {
                ops.push(CopyOp::Memcpy {
                    src_blob: nr,
                    src_off: 0,
                    dst_blob: nr,
                    dst_off: 0,
                    len,
                });
            }
        }
        return CopyProgram {
            count: sp.count(),
            dst_count: dp.count(),
            method: CopyMethod::Blobwise,
            ops,
        };
    }
    compile_range_with(src, dst, sp, dp, order, 0, sp.count())
}

/// Compile the record range `start..end` with the best non-identical
/// strategy: span-merged chunk runs, strided runs, swap runs, or
/// gather. A range is a slice with equal offsets on both sides.
pub(crate) fn compile_range_with<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
    start: usize,
    end: usize,
) -> CopyProgram {
    compile_slice_ordered(src, dst, sp, dp, order, start, start, end.saturating_sub(start))
}

/// [`CopyProgram::compile_slice`] over plans the caller already
/// compiled.
pub(crate) fn compile_slice_with<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    src_start: usize,
    dst_start: usize,
    len: usize,
) -> CopyProgram {
    assert!(
        src.info().dim == dst.info().dim,
        "slice program between different record dimensions: {} vs {}",
        src.mapping_name(),
        dst.mapping_name()
    );
    assert!(
        src_start.checked_add(len).is_some_and(|e| e <= sp.count())
            && dst_start.checked_add(len).is_some_and(|e| e <= dp.count()),
        "slice src {src_start}+{len} / dst {dst_start}+{len} out of bounds ({} / {} records)",
        sp.count(),
        dp.count()
    );
    compile_slice_ordered(src, dst, sp, dp, ChunkOrder::ReadContiguous, src_start, dst_start, len)
}

/// The shared slice compiler behind ranges and slices: source records
/// `src_start .. src_start + len` land at destination records
/// `dst_start .. dst_start + len`, each side addressed at its own
/// offset.
#[allow(clippy::too_many_arguments)]
fn compile_slice_ordered<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
    src_start: usize,
    dst_start: usize,
    len: usize,
) -> CopyProgram {
    if plans_chunk_compatible(sp, dp) {
        compile_chunk_slice(src, dst, sp, dp, order, src_start, dst_start, len)
    } else if plans_strided_compatible(sp, dp) {
        compile_strided_slice(src, sp, dp, src_start, dst_start, len)
    } else if plans_swap_compatible(sp, dp) {
        compile_swap_slice(src, sp, dp, src_start, dst_start, len)
    } else {
        let ops = if len > 0 {
            vec![CopyOp::Gather { src_start, dst_start, len }]
        } else {
            Vec::new()
        };
        CopyProgram {
            count: sp.count(),
            dst_count: dp.count(),
            method: CopyMethod::FieldWise,
            ops,
        }
    }
}

/// The chunked strategy: walk lane-blocks of the contiguous side and
/// emit one span per run intersection, coalescing adjacent spans. Run
/// lengths are capped at both plans' `chunk_lanes` — for Splits the
/// gcd of the children's lanes, the longest run contiguous on *every*
/// routed child.
#[allow(clippy::too_many_arguments)]
fn compile_chunk_slice<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
    src_start: usize,
    dst_start: usize,
    len: usize,
) -> CopyProgram {
    let src_lanes = sp.chunk_lanes().expect("chunk strategy needs src chunk_lanes");
    let dst_lanes = dp.chunk_lanes().expect("chunk strategy needs dst chunk_lanes");
    let info = src.info().clone();
    let leaves = info.leaf_count();
    let end = src_start + len;
    // Next outer-block boundary after `pos`, in *source* coordinates:
    // the chosen side's lane blocks, the destination's translated by
    // the slice offset (equal offsets reduce to the range walk).
    let boundary = |pos: usize| match order {
        ChunkOrder::ReadContiguous => ((pos / src_lanes) + 1) * src_lanes,
        ChunkOrder::WriteContiguous => {
            let dpos = pos - src_start + dst_start;
            ((dpos / dst_lanes) + 1) * dst_lanes - dst_start + src_start
        }
    };
    let mut sink = OpSink::new();
    let mut block_start = src_start;
    while block_start < end {
        let block_end = boundary(block_start).min(end);
        for leaf in 0..leaves {
            let size = info.fields[leaf].size();
            let mut pos = block_start;
            while pos < block_end {
                let dpos = pos - src_start + dst_start;
                // Largest run not crossing a lane boundary on either
                // side (plan.rs span helpers), each side at its own
                // offset.
                let run = block_end
                    .min(sp.chunk_run_end(pos).expect("src chunkable"))
                    .min(dp.chunk_run_end(dpos).expect("dst chunkable") - dst_start + src_start);
                let (snr, soff) = sp.resolve_with(src, leaf, pos);
                let (dnr, doff) = dp.resolve_with(dst, leaf, dpos);
                sink.memcpy(snr, soff, dnr, doff, (run - pos) * size);
                pos = run;
            }
        }
        block_start = block_end;
    }
    CopyProgram {
        count: sp.count(),
        dst_count: dp.count(),
        method: CopyMethod::AoSoAChunked,
        ops: sink.ops,
    }
}

/// The affine strategy: one op per leaf over the whole range. Leaves
/// contiguous on both sides (stride == element size) become `Memcpy`
/// spans; everything else a `StridedRun`.
fn compile_strided_slice<MS: Mapping + ?Sized>(
    src: &MS,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    src_start: usize,
    dst_start: usize,
    len: usize,
) -> CopyProgram {
    let info = src.info().clone();
    let mut sink = OpSink::new();
    if len > 0 {
        for leaf in 0..info.leaf_count() {
            let e = info.fields[leaf].size();
            let a = sp.affine_leaf(leaf).expect("strided strategy needs affine src");
            let b = dp.affine_leaf(leaf).expect("strided strategy needs affine dst");
            if a.stride == e && b.stride == e {
                let (so, doff) = (a.base + src_start * e, b.base + dst_start * e);
                sink.memcpy(a.blob, so, b.blob, doff, len * e);
            } else {
                sink.ops.push(CopyOp::StridedRun {
                    src_blob: a.blob,
                    src_off: a.base + src_start * a.stride,
                    src_stride: a.stride,
                    dst_blob: b.blob,
                    dst_off: b.base + dst_start * b.stride,
                    dst_stride: b.stride,
                    elem: e,
                    count: len,
                });
            }
        }
    }
    CopyProgram {
        count: sp.count(),
        dst_count: dp.count(),
        method: CopyMethod::Program,
        ops: sink.ops,
    }
}

/// The swap strategy: an affine pair with exactly one byteswapped side
/// ([`plans_swap_compatible`]). Same per-leaf shape as the strided
/// strategy, but every multi-byte leaf becomes a [`CopyOp::SwapRun`]
/// that reverses element bytes in flight — the `copy::wire` cross-endian
/// pack/unpack path. 1-byte leaves need no reversal and compile to the
/// verbatim ops of the strided strategy.
fn compile_swap_slice<MS: Mapping + ?Sized>(
    src: &MS,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    src_start: usize,
    dst_start: usize,
    len: usize,
) -> CopyProgram {
    let info = src.info().clone();
    let mut sink = OpSink::new();
    if len > 0 {
        for leaf in 0..info.leaf_count() {
            let e = info.fields[leaf].size();
            let a = sp.affine_leaf(leaf).expect("swap strategy needs affine src");
            let b = dp.affine_leaf(leaf).expect("swap strategy needs affine dst");
            if e <= 1 {
                // Byte reversal of a 1-byte element is the identity.
                if a.stride == e && b.stride == e {
                    let (so, doff) = (a.base + src_start * e, b.base + dst_start * e);
                    sink.memcpy(a.blob, so, b.blob, doff, len * e);
                } else {
                    sink.ops.push(CopyOp::StridedRun {
                        src_blob: a.blob,
                        src_off: a.base + src_start * a.stride,
                        src_stride: a.stride,
                        dst_blob: b.blob,
                        dst_off: b.base + dst_start * b.stride,
                        dst_stride: b.stride,
                        elem: e,
                        count: len,
                    });
                }
            } else {
                sink.ops.push(CopyOp::SwapRun {
                    src_blob: a.blob,
                    src_off: a.base + src_start * a.stride,
                    src_stride: a.stride,
                    dst_blob: b.blob,
                    dst_off: b.base + dst_start * b.stride,
                    dst_stride: b.stride,
                    elem: e,
                    count: len,
                });
            }
        }
    }
    CopyProgram {
        count: sp.count(),
        dst_count: dp.count(),
        method: CopyMethod::SwapProgram,
        ops: sink.ops,
    }
}

/// Split the record range into plan-aligned shards and compile one
/// sub-program per shard, for [`execute_parallel`]. Falls back to a
/// single full program (executed serially) when the pair has no
/// closed-form range strategy (gather, or identical layouts with
/// generic plans) or when the destination plan aliases records
/// (`One`) — concurrent shards would race on the aliased bytes.
pub fn shard_programs<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    threads: usize,
) -> Vec<CopyProgram> {
    let sp = src.plan();
    let dp = dst.plan();
    shard_programs_with(src, dst, &sp, &dp, ChunkOrder::ReadContiguous, threads)
}

pub(crate) fn shard_programs_with<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
    threads: usize,
) -> Vec<CopyProgram> {
    let n = sp.count();
    // Same predicate set as `compile_range_with`'s strategy choice, so
    // sharded ranges can never land on the unshardable gather fallback.
    let closed_range_form = plans_chunk_compatible(sp, dp)
        || plans_strided_compatible(sp, dp)
        || plans_swap_compatible(sp, dp);
    // Identical layouts keep the single per-blob memcpy program: a
    // memcpy is already memory-bound, and the dispatcher keeps
    // reporting `Blobwise`.
    if threads <= 1
        || n == 0
        || !closed_range_form
        || layouts_identical_with(src, dst, sp, dp)
    {
        return vec![compile_with(src, dst, sp, dp, order)];
    }
    shard_pair(sp, dp, threads)
        .into_iter()
        .map(|s| compile_range_with(src, dst, sp, dp, order, s.start, s.end))
        .collect()
}

/// Below this record count, thread-spawn overhead dominates any copy
/// win: every parallel entry point falls back to one serial program.
const PAR_MIN_RECORDS: usize = 1024;

/// Shared worker-count policy of the parallel copy entry points
/// (`run_parallel_with`, [`ProgramCache::copy_parallel`]): default to
/// the machine's parallelism, never exceed the record count, and run
/// serially below [`PAR_MIN_RECORDS`].
fn resolve_threads(n: usize, threads: Option<usize>) -> usize {
    let threads = threads
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
        .min(n.max(1));
    if n < PAR_MIN_RECORDS {
        1
    } else {
        threads
    }
}

/// The one shared parallel-copy body behind [`super::copy_parallel`]
/// and [`super::copy_aosoa_parallel`]: clamp the thread count, fall
/// back to a single program below [`PAR_MIN_RECORDS`], shard,
/// execute, and report the strategy used.
pub(crate) fn run_parallel_with<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
    order: ChunkOrder,
    threads: Option<usize>,
) -> CopyMethod
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob + Sync,
    BD: BlobMut,
{
    let threads = resolve_threads(src.count(), threads);
    let progs = shard_programs_with(src.mapping(), dst.mapping(), sp, dp, order, threads);
    let method = progs[0].method();
    execute_parallel(&progs, src, dst);
    method
}

/// Fingerprint of a (src, dst) layout pair: the two compiled plans
/// plus the blob shapes and leaf sizes — everything the program
/// compiler's output depends on for closed-form pairs. Generic plans
/// are excluded from caching entirely (see [`ProgramCache`]): their
/// byte placement lives in the mapping object, which two distinct
/// mappings with equal generic plans need not share.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PairKey {
    src: LayoutPlan,
    dst: LayoutPlan,
    src_blob_sizes: Vec<usize>,
    dst_blob_sizes: Vec<usize>,
    leaf_sizes: Vec<usize>,
    /// Worker count the sharded program list was compiled for (0 =
    /// the serial single-program entry).
    threads: usize,
}

impl PairKey {
    fn new<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
        src: &MS,
        dst: &MD,
        sp: &LayoutPlan,
        dp: &LayoutPlan,
        threads: usize,
    ) -> PairKey {
        PairKey {
            src: sp.clone(),
            dst: dp.clone(),
            src_blob_sizes: (0..src.blob_count()).map(|b| src.blob_size(b)).collect(),
            dst_blob_sizes: (0..dst.blob_count()).map(|b| dst.blob_size(b)).collect(),
            leaf_sizes: src.info().fields.iter().map(|f| f.size()).collect(),
            threads,
        }
    }
}

/// A memoized program compiler: repeated copies between the same
/// (src plan, dst plan) pair — the adaptive engine's migrations, frame
/// reshuffles, double-buffer flips — compile **once** and replay the
/// cached op list thereafter.
///
/// Only pairs whose plans are both closed-form (non-generic
/// addressing) are cached: a closed-form plan fully determines byte
/// placement, so together with the blob shapes and leaf sizes in the
/// key it is a sound fingerprint. Generic pairs (instrumented,
/// represented, curve layouts) compile fresh on every call — their
/// placement lives in the mapping object, which the fingerprint cannot
/// see.
///
/// The cache is `Sync`: every method takes `&self`, entries live
/// behind an internal mutex, and compiled program lists are shared as
/// `Arc` slices so execution never holds the lock. One cache serves
/// the whole serving fleet ([`crate::view::serve`]) — migrations of
/// different stores with the same layout pair compile once, and racing
/// first-compilers resolve first-insert-wins (the loser's identical
/// list is dropped).
#[derive(Debug, Default)]
pub struct ProgramCache {
    programs: std::sync::Mutex<std::collections::HashMap<PairKey, std::sync::Arc<[CopyProgram]>>>,
    hits: std::sync::atomic::AtomicUsize,
}

impl ProgramCache {
    /// An empty cache.
    pub fn new() -> ProgramCache {
        ProgramCache::default()
    }

    /// Number of distinct (pair, thread-count) entries compiled so far.
    pub fn entries(&self) -> usize {
        self.programs.lock().unwrap().len()
    }

    /// Number of lookups served from the cache (tests assert repeated
    /// migrations compile once).
    pub fn hits(&self) -> usize {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn cacheable(sp: &LayoutPlan, dp: &LayoutPlan) -> bool {
        use crate::mapping::AddrPlan;
        !matches!(sp.addr(), AddrPlan::Generic) && !matches!(dp.addr(), AddrPlan::Generic)
    }

    fn programs_for<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
        &self,
        src: &MS,
        dst: &MD,
        sp: &LayoutPlan,
        dp: &LayoutPlan,
        threads: usize,
    ) -> std::sync::Arc<[CopyProgram]> {
        use std::sync::atomic::Ordering;
        let compile = |threads: usize| -> std::sync::Arc<[CopyProgram]> {
            if threads == 0 {
                vec![compile_with(src, dst, sp, dp, ChunkOrder::ReadContiguous)].into()
            } else {
                shard_programs_with(src, dst, sp, dp, ChunkOrder::ReadContiguous, threads).into()
            }
        };
        if !Self::cacheable(sp, dp) {
            return compile(threads);
        }
        let key = PairKey::new(src, dst, sp, dp, threads);
        if let Some(progs) = self.programs.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return std::sync::Arc::clone(progs);
        }
        // Compile outside the lock — program compilation walks both
        // plans and can be arbitrarily long. Two threads racing on the
        // same new pair both compile; the first insert wins and both
        // results are identical by construction.
        let compiled = compile(threads);
        std::sync::Arc::clone(
            self.programs.lock().unwrap().entry(key).or_insert(compiled),
        )
    }

    /// [`super::copy`] through the cache: compile (or look up) the
    /// serial program for the pair, execute it, report the strategy.
    pub fn copy<MS, MD, BS, BD>(&self, src: &View<MS, BS>, dst: &mut View<MD, BD>) -> CopyMethod
    where
        MS: Mapping,
        MD: Mapping,
        BS: Blob,
        BD: BlobMut,
    {
        let sp = src.mapping().plan();
        let dp = dst.mapping().plan();
        let progs = self.programs_for(src.mapping(), dst.mapping(), &sp, &dp, 0);
        let method = progs[0].method();
        progs[0].execute(src, dst);
        method
    }

    /// Compile (or look up) the sharded program list for a mapping
    /// pair and hand it to `f` — callers that must inspect the ops
    /// *before* touching a destination use this: the adaptive engine
    /// checks [`programs_cover_dst`] to decide whether a recycled
    /// destination needs its re-zero, allocates, and then executes the
    /// same list via [`execute_parallel`]. Thread resolution and cache
    /// accounting match [`ProgramCache::copy_parallel`] exactly.
    pub fn with_parallel_programs<MS, MD, T>(
        &self,
        src: &MS,
        dst: &MD,
        threads: Option<usize>,
        f: impl FnOnce(&[CopyProgram]) -> T,
    ) -> T
    where
        MS: Mapping + ?Sized,
        MD: Mapping + ?Sized,
    {
        let threads = resolve_threads(src.dims().count(), threads);
        let sp = src.plan();
        let dp = dst.plan();
        let progs = self.programs_for(src, dst, &sp, &dp, threads);
        f(&progs)
    }

    /// [`super::copy_parallel`] through the cache: compile (or look
    /// up) one sub-program per plan-aligned shard and replay them on
    /// scoped threads — the adaptive engine's `migrate_parallel` path.
    pub fn copy_parallel<MS, MD, BS, BD>(
        &self,
        src: &View<MS, BS>,
        dst: &mut View<MD, BD>,
        threads: Option<usize>,
    ) -> CopyMethod
    where
        MS: Mapping,
        MD: Mapping,
        BS: Blob + Sync,
        BD: BlobMut,
    {
        let threads = resolve_threads(src.count(), threads);
        let sp = src.mapping().plan();
        let dp = dst.mapping().plan();
        let progs = self.programs_for(src.mapping(), dst.mapping(), &sp, &dp, threads);
        let method = progs[0].method();
        execute_parallel(&progs, src, dst);
        method
    }
}

/// True if executing `programs` writes **every** byte of every
/// destination blob (`dst_blob_sizes[nr]` bytes each), padding
/// included — the static proof that lets a recycled destination skip
/// its re-zero ([`crate::blob::BlobRecycler::allocate_covered`]; the
/// adaptive engine checks this before drawing migration destinations
/// from its pool).
///
/// The proof is purely structural, over the compiled ops:
///
/// * `Memcpy` spans and contiguous `StridedRun`s/`SwapRun`s
///   (stride == elem) cover their byte ranges directly — a swap run
///   writes the same bytes as a strided run, just reordered within
///   each element.
/// * Gapped `StridedRun`s/`SwapRun`s are grouped into interleaved
///   families (same destination blob, stride and count): when a
///   family's pieces tile one full period — per-leaf runs into a
///   packed-AoS destination — the family covers its whole
///   `count * stride` range.
/// * `Gather` ops resolve through the mappings at execution time, so
///   they never prove coverage.
///
/// Conservative by construction: `false` means "re-zero", never an
/// unsound skip. Aligned destinations with padding holes (aligned AoS,
/// AoSoA tail blocks) correctly report `false`, and **all** span
/// arithmetic is overflow-checked — an op list whose extents wrap
/// `usize` (a corrupt or adversarial program, e.g. from a forged wire
/// manifest) can never alias a small in-bounds span and falsely prove
/// coverage; it reports `false` instead.
pub fn programs_cover_dst(programs: &[CopyProgram], dst_blob_sizes: &[usize]) -> bool {
    /// A gapped strided run awaiting the family analysis:
    /// (program index, dst offset, dst stride, element size, count).
    type GappedRun = (usize, usize, usize, usize, usize);
    let nblobs = dst_blob_sizes.len();
    // Per blob: directly-covered byte spans and gapped strided runs.
    let mut dense: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nblobs];
    let mut strided: Vec<Vec<GappedRun>> = vec![Vec::new(); nblobs];
    for (pi, p) in programs.iter().enumerate() {
        for op in p.ops() {
            match *op {
                CopyOp::Memcpy { dst_blob, dst_off, len, .. } => {
                    if dst_blob >= nblobs {
                        return false;
                    }
                    if len > 0 {
                        match dst_off.checked_add(len) {
                            Some(end) => dense[dst_blob].push((dst_off, end)),
                            None => return false,
                        }
                    }
                }
                CopyOp::StridedRun { dst_blob, dst_off, dst_stride, elem, count, .. }
                | CopyOp::SwapRun { dst_blob, dst_off, dst_stride, elem, count, .. } => {
                    if dst_blob >= nblobs {
                        return false;
                    }
                    if elem == 0 || count == 0 {
                        continue;
                    }
                    if dst_stride == elem {
                        match count.checked_mul(elem).and_then(|b| dst_off.checked_add(b)) {
                            Some(end) => dense[dst_blob].push((dst_off, end)),
                            None => return false,
                        }
                    } else {
                        strided[dst_blob].push((pi, dst_off, dst_stride, elem, count));
                    }
                }
                CopyOp::Gather { len, .. } => {
                    if len > 0 {
                        return false;
                    }
                }
            }
        }
    }
    for (nr, &size) in dst_blob_sizes.iter().enumerate() {
        if size == 0 {
            continue;
        }
        let spans = &mut dense[nr];
        // Group gapped runs into per-program (stride, count) families
        // and check whether each family's pieces tile one full period.
        // Families never span sub-programs: a sharded list's shards
        // tile their own record ranges independently (equal-length
        // shards would otherwise collide on (stride, count)).
        let mut fams: std::collections::BTreeMap<(usize, usize, usize), Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for &(pi, off, stride, elem, count) in &strided[nr] {
            fams.entry((pi, stride, count)).or_default().push((off, elem));
        }
        for ((_pi, stride, count), mut pieces) in fams {
            pieces.sort_unstable();
            let r0 = pieces[0].0;
            let mut covered = 0usize; // within [0, stride)
            let mut tiles = true;
            for (off, elem) in pieces {
                let a = off - r0;
                let piece_end = match a.checked_add(elem) {
                    Some(e) => e,
                    None => return false,
                };
                if a > covered || piece_end > stride {
                    tiles = false;
                    break;
                }
                covered = covered.max(piece_end);
            }
            if tiles && covered >= stride {
                match count.checked_mul(stride).and_then(|b| r0.checked_add(b)) {
                    Some(end) => spans.push((r0, end)),
                    None => return false,
                }
            }
            // Non-tiling families contribute nothing: their gaps make
            // the final check fail closed.
        }
        spans.sort_unstable();
        let mut covered = 0usize;
        for &(a, b) in spans.iter() {
            if a > covered {
                return false;
            }
            covered = covered.max(b);
        }
        if covered < size {
            return false;
        }
    }
    true
}

/// Base pointers + lengths of the destination blobs, shared across the
/// worker threads (same soundness argument as `copy::parallel`: the
/// sub-programs' destination byte ranges are disjoint because their
/// record shards are, by the fundamental mapping invariant).
struct RawDst {
    ptrs: Vec<(*mut u8, usize)>,
}

// SAFETY: workers write disjoint ranges (see above).
unsafe impl Send for RawDst {}
unsafe impl Sync for RawDst {}

/// Execute sharded sub-programs concurrently (one scoped worker per
/// program; a single program runs inline). All programs must be
/// closed-form ([`CopyProgram::is_closed_form`]) — [`shard_programs`]
/// only produces such lists.
pub fn execute_parallel<MS, MD, BS, BD>(
    programs: &[CopyProgram],
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
) where
    MS: Mapping,
    MD: Mapping,
    BS: Blob + Sync,
    BD: BlobMut,
{
    execute_parallel_with(programs, src, dst, detect());
}

/// [`execute_parallel`] on an explicit [`SimdPath`] (see
/// [`CopyProgram::execute_with_path`]); unusable paths fall back to
/// scalar word moves.
pub fn execute_parallel_with<MS, MD, BS, BD>(
    programs: &[CopyProgram],
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    path: SimdPath,
) where
    MS: Mapping,
    MD: Mapping,
    BS: Blob + Sync,
    BD: BlobMut,
{
    let path = if path.is_vector() { path } else { SimdPath::Scalar };
    match programs {
        [] => {}
        [p] => p.execute_with_path(src, dst, path),
        _ => {
            // Same contract as the serial `execute` path: reject views
            // the programs were not compiled for instead of silently
            // copying a prefix.
            for p in programs {
                assert_eq!(p.count(), src.count(), "program compiled for a different extent");
                assert_eq!(p.dst_count(), dst.count(), "program compiled for a different extent");
            }
            assert!(
                programs.iter().all(|p| p.is_closed_form()),
                "gather ops cannot be executed concurrently"
            );
            let (_, dblobs) = dst.mapping_and_blobs_mut();
            let raw = RawDst {
                ptrs: dblobs
                    .iter_mut()
                    .map(|b| {
                        let s = b.as_bytes_mut();
                        (s.as_mut_ptr(), s.len())
                    })
                    .collect(),
            };
            std::thread::scope(|scope| {
                for p in programs {
                    let raw = &raw;
                    scope.spawn(move || {
                        for op in p.ops() {
                            // SAFETY: bounds asserted inside; dst
                            // ranges disjoint across programs.
                            unsafe { execute_op_raw(op, src, raw, path) };
                        }
                    });
                }
            });
        }
    }
}

/// Execute one closed-form op through raw destination pointers.
///
/// # Safety
/// `raw` must point into live destination blobs; concurrent callers
/// must hold disjoint op sets (guaranteed by [`shard_programs`]'s
/// disjoint record shards + the mapping invariant).
unsafe fn execute_op_raw<MS, BS>(op: &CopyOp, src: &View<MS, BS>, raw: &RawDst, path: SimdPath)
where
    MS: Mapping,
    BS: Blob,
{
    match *op {
        CopyOp::Memcpy { src_blob, src_off, dst_blob, dst_off, len } => {
            let sbytes = src.blobs()[src_blob].as_bytes();
            let (dptr, dlen) = raw.ptrs[dst_blob];
            assert!(src_off + len <= sbytes.len() && dst_off + len <= dlen);
            std::ptr::copy_nonoverlapping(sbytes.as_ptr().add(src_off), dptr.add(dst_off), len);
        }
        CopyOp::StridedRun {
            src_blob,
            src_off,
            src_stride,
            dst_blob,
            dst_off,
            dst_stride,
            elem,
            count,
        } => {
            if count == 0 {
                return;
            }
            let sbytes = src.blobs()[src_blob].as_bytes();
            let (dptr, dlen) = raw.ptrs[dst_blob];
            assert!(
                src_off + (count - 1) * src_stride + elem <= sbytes.len()
                    && dst_off + (count - 1) * dst_stride + elem <= dlen
            );
            crate::view::simd::strided_run_raw(
                path,
                sbytes.as_ptr().add(src_off),
                src_stride,
                dptr.add(dst_off),
                dst_stride,
                elem,
                count,
            );
        }
        CopyOp::SwapRun {
            src_blob,
            src_off,
            src_stride,
            dst_blob,
            dst_off,
            dst_stride,
            elem,
            count,
        } => {
            if count == 0 {
                return;
            }
            let sbytes = src.blobs()[src_blob].as_bytes();
            let (dptr, dlen) = raw.ptrs[dst_blob];
            assert!(
                src_off + (count - 1) * src_stride + elem <= sbytes.len()
                    && dst_off + (count - 1) * dst_stride + elem <= dlen
            );
            let sptr = sbytes.as_ptr();
            for i in 0..count {
                let s = sptr.add(src_off + i * src_stride);
                let d = dptr.add(dst_off + i * dst_stride);
                for b in 0..elem {
                    *d.add(b) = *s.add(elem - 1 - b);
                }
            }
        }
        CopyOp::Gather { .. } => unreachable!("gather ops are never sharded"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::fill_distinct;
    use crate::copy::{copy_naive, views_equal};
    use crate::mapping::plan::AddrPlan;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA, Split};
    use crate::record::{RecordCoord, RecordDim, Scalar};
    use crate::view::alloc_view;

    fn xy() -> RecordDim {
        RecordDim::new().scalar("x", Scalar::F32).scalar("y", Scalar::F32)
    }

    /// Differential helper: program execution must be bit-identical to
    /// the naive oracle on fresh destinations.
    fn check_against_naive<MS: Mapping + Clone, MD: Mapping + Clone>(src_m: MS, dst_m: MD) {
        let mut src = alloc_view(src_m);
        fill_distinct(&mut src);
        let mut oracle = alloc_view(dst_m.clone());
        copy_naive(&src, &mut oracle);
        let prog = CopyProgram::compile(src.mapping(), &dst_m);
        let mut got = alloc_view(dst_m);
        prog.execute(&src, &mut got);
        assert_eq!(got.blobs(), oracle.blobs(), "program != naive oracle");
        assert!(views_equal(&src, &got));
    }

    #[test]
    fn chunk_slices_tile_the_range_on_aligned_cuts() {
        for (begin, end, target, align) in [
            (0usize, 100usize, 32usize, 8usize),
            (0, 100, 30, 8),  // target rounds down to 24
            (5, 97, 16, 16),  // interior cuts at begin + k·16
            (0, 7, 100, 8),   // one chunk: target exceeds the range
            (0, 64, 4, 16),   // target below align: whole blocks anyway
            (0, 33, 1, 1),    // degenerate: per-record chunks
            (0, 10, 0, 0),    // zero target/align clamp to 1
        ] {
            let chunks = CopyProgram::chunk_slices(begin, end, target, align);
            assert!(!chunks.is_empty(), "{begin}..{end} produced no chunks");
            // Exact tiling: consecutive, disjoint, covering.
            assert_eq!(chunks[0].0, begin);
            assert_eq!(chunks.last().unwrap().1, end);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap or overlap in {chunks:?}");
            }
            let align = align.max(1);
            for (i, (b, e)) in chunks.iter().enumerate() {
                assert!(b < e, "empty chunk in {chunks:?}");
                assert!(e - b <= target.max(align), "oversized chunk in {chunks:?}");
                if i > 0 {
                    assert_eq!((b - begin) % align, 0, "unaligned cut in {chunks:?}");
                }
            }
        }
        assert!(CopyProgram::chunk_slices(5, 5, 8, 4).is_empty(), "empty range");
    }

    // --- Golden byte-layout snapshots (3-record extents): the exact
    // op list a compiled program emits. Catches silent coalescing
    // regressions — these lists are the contract of the compiler.

    #[test]
    fn golden_aos_to_soa_mb() {
        let m_src = AoS::packed(&xy(), ArrayDims::linear(3));
        let m_dst = SoA::multi_blob(&xy(), ArrayDims::linear(3));
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::AoSoAChunked);
        // Packed AoS chunks at 1 lane: per record, x goes to blob 0 and
        // y to blob 1 — source-adjacent but destination-alternating, so
        // nothing coalesces.
        assert_eq!(
            prog.ops(),
            &[
                CopyOp::Memcpy { src_blob: 0, src_off: 0, dst_blob: 0, dst_off: 0, len: 4 },
                CopyOp::Memcpy { src_blob: 0, src_off: 4, dst_blob: 1, dst_off: 0, len: 4 },
                CopyOp::Memcpy { src_blob: 0, src_off: 8, dst_blob: 0, dst_off: 4, len: 4 },
                CopyOp::Memcpy { src_blob: 0, src_off: 12, dst_blob: 1, dst_off: 4, len: 4 },
                CopyOp::Memcpy { src_blob: 0, src_off: 16, dst_blob: 0, dst_off: 8, len: 4 },
                CopyOp::Memcpy { src_blob: 0, src_off: 20, dst_blob: 1, dst_off: 8, len: 4 },
            ]
        );
        check_against_naive(m_src, m_dst);
    }

    #[test]
    fn golden_aosoa4_to_aosoa8() {
        // 3 records: one partial block on both sides; each field's
        // 3-element run is contiguous in both layouts, the two fields'
        // runs are separated by tail padding — exactly 2 spans.
        let m_src = AoSoA::new(&xy(), ArrayDims::linear(3), 4);
        let m_dst = AoSoA::new(&xy(), ArrayDims::linear(3), 8);
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::AoSoAChunked);
        assert_eq!(
            prog.ops(),
            &[
                CopyOp::Memcpy { src_blob: 0, src_off: 0, dst_blob: 0, dst_off: 0, len: 12 },
                CopyOp::Memcpy { src_blob: 0, src_off: 16, dst_blob: 0, dst_off: 32, len: 12 },
            ]
        );
        check_against_naive(m_src, m_dst);
    }

    #[test]
    fn golden_blobwise_identical_is_one_memcpy_per_blob() {
        let dims = ArrayDims::linear(3);
        let prog = CopyProgram::compile(
            &SoA::multi_blob(&xy(), dims.clone()),
            &SoA::multi_blob(&xy(), dims.clone()),
        );
        assert_eq!(prog.method(), CopyMethod::Blobwise);
        assert_eq!(
            prog.ops(),
            &[
                CopyOp::Memcpy { src_blob: 0, src_off: 0, dst_blob: 0, dst_off: 0, len: 12 },
                CopyOp::Memcpy { src_blob: 1, src_off: 0, dst_blob: 1, dst_off: 0, len: 12 },
            ]
        );
        // Single-blob identical AoSoA: one span covering the whole blob
        // including the tail-block padding.
        let prog = CopyProgram::compile(
            &AoSoA::new(&xy(), dims.clone(), 4),
            &AoSoA::new(&xy(), dims.clone(), 4),
        );
        assert_eq!(prog.method(), CopyMethod::Blobwise);
        assert_eq!(
            prog.ops(),
            &[CopyOp::Memcpy { src_blob: 0, src_off: 0, dst_blob: 0, dst_off: 0, len: 32 }]
        );
    }

    #[test]
    fn golden_affine_pair_compiles_strided_runs() {
        // Aligned AoS is not chunkable (for a 2×f32 record aligned ==
        // packed in size, but the plan still reports no chunk lanes) —
        // the affine strategy emits one strided run per leaf.
        let m_src = AoS::aligned(&xy(), ArrayDims::linear(3));
        let m_dst = SoA::multi_blob(&xy(), ArrayDims::linear(3));
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::Program);
        assert_eq!(
            prog.ops(),
            &[
                CopyOp::StridedRun {
                    src_blob: 0,
                    src_off: 0,
                    src_stride: 8,
                    dst_blob: 0,
                    dst_off: 0,
                    dst_stride: 4,
                    elem: 4,
                    count: 3
                },
                CopyOp::StridedRun {
                    src_blob: 0,
                    src_off: 4,
                    src_stride: 8,
                    dst_blob: 1,
                    dst_off: 0,
                    dst_stride: 4,
                    elem: 4,
                    count: 3
                },
            ]
        );
        check_against_naive(m_src, m_dst);
    }

    #[test]
    fn strided_runs_copy_identical_bytes_on_every_simd_path() {
        // Aligned AoS -> SoA MB over the full demo record: 8-, 4-, 2-
        // and 1-byte leaves hit the gather kernels (elem 4/8, with
        // scalar tails at 133 % 8 records) and the per-element fallback
        // (elem 1/2). Serial and raw-pointer parallel sites both sweep.
        let d = particle_dim();
        let dims = ArrayDims::linear(133);
        let m_src = AoS::aligned(&d, dims.clone());
        let m_dst = SoA::multi_blob(&d, dims.clone());
        let mut src = alloc_view(m_src);
        fill_distinct(&mut src);
        let prog = CopyProgram::compile(src.mapping(), &m_dst);
        assert_eq!(prog.method(), CopyMethod::Program);
        assert!(prog.ops().iter().any(|op| matches!(op, CopyOp::StridedRun { .. })));
        let mut oracle = alloc_view(m_dst.clone());
        copy_naive(&src, &mut oracle);
        for path in crate::view::simd::available_paths() {
            let mut dst = alloc_view(m_dst.clone());
            prog.execute_with_path(&src, &mut dst, path);
            assert_eq!(dst.blobs(), oracle.blobs(), "serial path {path:?}");
            let progs = shard_programs(src.mapping(), &m_dst, 3);
            let mut par = alloc_view(m_dst.clone());
            execute_parallel_with(&progs, &src, &mut par, path);
            assert_eq!(par.blobs(), oracle.blobs(), "parallel path {path:?}");
        }
    }

    #[test]
    fn aosoa_pairs_compile_to_bounded_runs() {
        // AoSoA-N ↔ AoSoA-M: run intersections are between gcd(N, M)
        // and min(N, M) records of one field; no span may cross a lane
        // boundary of either side (the smallest leaf is guaranteed to
        // produce a pure gcd-sized span somewhere).
        let d = particle_dim();
        let dims = ArrayDims::linear(48);
        let prog = CopyProgram::compile(
            &AoSoA::new(&d, dims.clone(), 4),
            &AoSoA::new(&d, dims.clone(), 6),
        );
        assert_eq!(prog.method(), CopyMethod::AoSoAChunked);
        let mut saw_gcd_span = false;
        for op in prog.ops() {
            if let CopyOp::Memcpy { len, .. } = op {
                // min(4, 6) = 4 records; the largest leaf is 8 bytes.
                assert!(*len <= 4 * 8, "span {op:?} crosses a lane boundary");
                // gcd(4, 6) = 2 records of the 1-byte bool leaves.
                saw_gcd_span |= *len == 2;
            }
        }
        assert!(saw_gcd_span, "no gcd-sized span — intersections not derived per leaf");
        check_against_naive(AoSoA::new(&d, dims.clone(), 4), AoSoA::new(&d, dims, 6));
    }

    #[test]
    fn split_chunk_lanes_gcd_regression() {
        // Split children with lane counts 4 and 8 over a 13-record
        // extent (tail block): compose_split gcds the chunk lanes to 4
        // and the compiler must cap runs there — and for
        // Split(AoSoA4, packed AoS) the piecewise *addressing* lanes
        // (4) exceed the chunkable run (gcd(4,1) = 1); using the
        // addressing lanes would emit non-contiguous "runs".
        let d = particle_dim();
        let dims = ArrayDims::linear(13);
        let split48 = || {
            Split::new(
                &d,
                dims.clone(),
                RecordCoord::new(vec![1]),
                |sd, ad| AoSoA::new(sd, ad, 4),
                |sd, ad| AoSoA::new(sd, ad, 8),
            )
        };
        let plan = split48().plan();
        assert_eq!(plan.chunk_lanes(), Some(4));
        check_against_naive(split48(), SoA::multi_blob(&d, dims.clone()));
        check_against_naive(SoA::multi_blob(&d, dims.clone()), split48());

        let split41 = || {
            Split::new(
                &d,
                dims.clone(),
                RecordCoord::new(vec![1]),
                |sd, ad| AoSoA::new(sd, ad, 4),
                |sd, ad| AoS::packed(sd, ad),
            )
        };
        let plan = split41().plan();
        assert!(matches!(plan.addr(), AddrPlan::PiecewiseAoSoA(p) if p.lanes == 4));
        assert_eq!(plan.chunk_lanes(), Some(1));
        check_against_naive(split41(), SoA::multi_blob(&d, dims.clone()));
        check_against_naive(AoS::packed(&d, dims.clone()), split41());
    }

    #[test]
    fn chunk_orders_copy_identical_bytes() {
        let d = particle_dim();
        let dims = ArrayDims::linear(37);
        let src_m = AoSoA::new(&d, dims.clone(), 4);
        let dst_m = AoSoA::new(&d, dims.clone(), 16);
        let mut src = alloc_view(src_m);
        fill_distinct(&mut src);
        let r = CopyProgram::compile_ordered(src.mapping(), &dst_m, ChunkOrder::ReadContiguous);
        let w = CopyProgram::compile_ordered(src.mapping(), &dst_m, ChunkOrder::WriteContiguous);
        let mut dr = alloc_view(dst_m.clone());
        let mut dw = alloc_view(dst_m);
        r.execute(&src, &mut dr);
        w.execute(&src, &mut dw);
        assert_eq!(dr.blobs(), dw.blobs());
        assert!(views_equal(&src, &dr));
    }

    #[test]
    fn sharded_programs_cover_and_match_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(257);
        let src_m = SoA::multi_blob(&d, dims.clone());
        let dst_m = AoSoA::new(&d, dims.clone(), 8);
        let mut src = alloc_view(src_m);
        fill_distinct(&mut src);
        let mut serial = alloc_view(dst_m.clone());
        CopyProgram::compile(src.mapping(), &dst_m).execute(&src, &mut serial);
        for threads in [2usize, 3, 7] {
            let progs = shard_programs(src.mapping(), &dst_m, threads);
            assert!(progs.len() <= threads && progs.len() > 1);
            let mut par = alloc_view(dst_m.clone());
            execute_parallel(&progs, &src, &mut par);
            assert_eq!(par.blobs(), serial.blobs(), "threads {threads}");
        }
    }

    #[test]
    fn aliasing_destination_collapses_to_one_program() {
        use crate::mapping::One;
        let d = particle_dim();
        let dims = ArrayDims::linear(64);
        let progs = shard_programs(&SoA::multi_blob(&d, dims.clone()), &One::new(&d, dims), 8);
        assert_eq!(progs.len(), 1);
    }

    #[test]
    fn gather_fallback_is_single_program() {
        use crate::array::MortonCurve;
        // A space-filling-curve layout has a generic plan — the only
        // remaining route to the gather fallback now that byteswapped
        // affine pairs compile to swap programs.
        let d = particle_dim();
        let dims = ArrayDims::from([4, 4]);
        let src_m = AoS::with_linearizer(&d, dims.clone(), MortonCurve, true);
        let dst_m = SoA::multi_blob(&d, dims.clone());
        let prog = CopyProgram::compile(&src_m, &dst_m);
        assert_eq!(prog.method(), CopyMethod::FieldWise);
        assert!(!prog.is_closed_form());
        assert_eq!(shard_programs(&src_m, &dst_m, 8).len(), 1);
        check_against_naive(src_m, dst_m);
    }

    #[test]
    fn golden_swap_pair_compiles_swap_runs() {
        use crate::mapping::Byteswap;
        // Byteswapped packed AoS → native SoA mb: a representation
        // mismatch over an affine pair — one 4-byte swap run per leaf.
        let m_src = Byteswap::new(AoS::packed(&xy(), ArrayDims::linear(3)));
        let m_dst = SoA::multi_blob(&xy(), ArrayDims::linear(3));
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::SwapProgram);
        assert!(prog.is_closed_form());
        assert_eq!(
            prog.ops(),
            &[
                CopyOp::SwapRun {
                    src_blob: 0,
                    src_off: 0,
                    src_stride: 8,
                    dst_blob: 0,
                    dst_off: 0,
                    dst_stride: 4,
                    elem: 4,
                    count: 3
                },
                CopyOp::SwapRun {
                    src_blob: 0,
                    src_off: 4,
                    src_stride: 8,
                    dst_blob: 1,
                    dst_off: 0,
                    dst_stride: 4,
                    elem: 4,
                    count: 3
                },
            ]
        );
        check_against_naive(m_src, m_dst);
        // The reverse direction (native → byteswapped, the wire pack
        // path) is equally closed-form.
        let m_src = SoA::multi_blob(&xy(), ArrayDims::linear(3));
        let m_dst = Byteswap::new(AoS::packed(&xy(), ArrayDims::linear(3)));
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::SwapProgram);
        assert!(prog.is_closed_form());
        check_against_naive(m_src, m_dst);
    }

    #[test]
    fn swap_programs_move_single_byte_leaves_verbatim() {
        use crate::mapping::Byteswap;
        // particle_dim has five multi-byte leaves (u16, 3×f32, f64) and
        // three 1-byte bool leaves. SoA mb → Byteswap(SoA mb) puts every
        // leaf at stride == elem: multi-byte leaves swap, 1-byte leaves
        // coalesce to plain memcpys (reversal is the identity).
        let d = particle_dim();
        let dims = ArrayDims::linear(13);
        let m_src = SoA::multi_blob(&d, dims.clone());
        let m_dst = Byteswap::new(SoA::multi_blob(&d, dims.clone()));
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::SwapProgram);
        assert!(prog.is_closed_form());
        let swaps =
            prog.ops().iter().filter(|op| matches!(op, CopyOp::SwapRun { .. })).count();
        let verbatim =
            prog.ops().iter().filter(|op| matches!(op, CopyOp::Memcpy { .. })).count();
        assert_eq!(swaps, 5, "one swap run per multi-byte leaf");
        assert_eq!(verbatim, 3, "1-byte leaves move verbatim");
        check_against_naive(m_src, m_dst);
    }

    #[test]
    fn swap_programs_shard_and_match_serial() {
        use crate::mapping::Byteswap;
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let m_src = Byteswap::new(AoS::packed(&d, dims.clone()));
        let m_dst = SoA::multi_blob(&d, dims.clone());
        let mut src = alloc_view(m_src.clone());
        fill_distinct(&mut src);
        let mut oracle = alloc_view(m_dst.clone());
        copy_naive(&src, &mut oracle);
        let prog = CopyProgram::compile(&m_src, &m_dst);
        assert_eq!(prog.method(), CopyMethod::SwapProgram);
        let mut serial = alloc_view(m_dst.clone());
        prog.execute(&src, &mut serial);
        assert_eq!(serial.blobs(), oracle.blobs(), "serial swap != naive oracle");
        for threads in [2usize, 5] {
            let progs = shard_programs(&m_src, &m_dst, threads);
            assert!(progs.len() > 1, "swap pairs must shard");
            let mut par = alloc_view(m_dst.clone());
            execute_parallel(&progs, &src, &mut par);
            assert_eq!(par.blobs(), oracle.blobs(), "threads {threads}");
        }
    }

    #[test]
    fn program_cache_compiles_once_per_pair() {
        let d = particle_dim();
        let dims = ArrayDims::linear(64);
        let cache = ProgramCache::new();
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut oracle = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        copy_naive(&src, &mut oracle);
        for round in 0..3 {
            let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 8));
            assert_eq!(cache.copy(&src, &mut dst), CopyMethod::AoSoAChunked);
            assert_eq!(dst.blobs(), oracle.blobs(), "round {round}");
        }
        assert_eq!(cache.entries(), 1, "repeated copies must reuse one program");
        assert_eq!(cache.hits(), 2);
        // The reverse direction is a different pair -> second entry.
        let mut back = alloc_view(SoA::multi_blob(&d, dims.clone()));
        let first = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        cache.copy(&first, &mut back);
        assert_eq!(cache.entries(), 2);
    }

    #[test]
    fn program_cache_parallel_matches_serial_and_caches_per_thread_count() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let cache = ProgramCache::new();
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut serial = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        CopyProgram::compile(src.mapping(), serial.mapping()).execute(&src, &mut serial);
        for _ in 0..2 {
            for threads in [2usize, 7] {
                let mut par = alloc_view(AoSoA::new(&d, dims.clone(), 16));
                assert_eq!(
                    cache.copy_parallel(&src, &mut par, Some(threads)),
                    CopyMethod::AoSoAChunked
                );
                assert_eq!(par.blobs(), serial.blobs(), "threads {threads}");
            }
        }
        // One entry per thread count, each compiled exactly once.
        assert_eq!(cache.entries(), 2);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn program_cache_never_caches_generic_pairs() {
        use crate::mapping::Trace;
        let d = particle_dim();
        let dims = ArrayDims::linear(16);
        let cache = ProgramCache::new();
        // Trace plans are generic: two different inner layouts would
        // collide on the plan fingerprint, so the cache must decline.
        let mut src = alloc_view(Trace::new(AoS::packed(&d, dims.clone())));
        fill_distinct(&mut src);
        let mut dst = alloc_view(SoA::multi_blob(&d, dims.clone()));
        // Still chunk-copyable (packed AoS chunks at 1 lane through the
        // mapping object) — but never cached.
        assert_eq!(cache.copy(&src, &mut dst), CopyMethod::AoSoAChunked);
        assert_eq!(cache.entries(), 0);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        copy_naive(&src, &mut oracle);
        assert_eq!(dst.blobs(), oracle.blobs());
    }

    fn dst_sizes<M: Mapping>(m: &M) -> Vec<usize> {
        (0..m.blob_count()).map(|b| m.blob_size(b)).collect()
    }

    #[test]
    fn coverage_proof_matches_the_strategy_table() {
        let d = particle_dim();
        let dims = ArrayDims::linear(64); // lane multiple of every case below
        let soa = SoA::multi_blob(&d, dims.clone());
        // Blobwise-identical: one memcpy per blob covers everything.
        let prog = CopyProgram::compile(&soa, &SoA::multi_blob(&d, dims.clone()));
        assert!(programs_cover_dst(&[prog], &dst_sizes(&soa)));
        // Chunked into SoA (no padding): covered.
        let prog = CopyProgram::compile(&AoSoA::new(&d, dims.clone(), 8), &soa);
        assert!(programs_cover_dst(&[prog], &dst_sizes(&soa)));
        // Chunked into an exact-multiple AoSoA (no tail padding): covered.
        let a8 = AoSoA::new(&d, dims.clone(), 8);
        let prog = CopyProgram::compile(&soa, &a8);
        assert!(programs_cover_dst(&[prog], &dst_sizes(&a8)));
        // Tail-block AoSoA destination: padding is never written.
        let dims17 = ArrayDims::linear(17);
        let a8t = AoSoA::new(&d, dims17.clone(), 8);
        let prog = CopyProgram::compile(&SoA::multi_blob(&d, dims17.clone()), &a8t);
        assert!(!programs_cover_dst(&[prog], &dst_sizes(&a8t)));
        // Aligned-AoS destination: strided runs skip the padding holes.
        let aos = AoS::aligned(&d, dims.clone());
        let prog = CopyProgram::compile(&soa, &aos);
        assert!(!programs_cover_dst(&[prog], &dst_sizes(&aos)));
        // Packed-AoS destination from aligned AoS: per-leaf strided
        // runs tile every record — the interleaved-family proof.
        let packed = AoS::packed(&d, dims.clone());
        let prog = CopyProgram::compile(&aos, &packed);
        assert_eq!(prog.method(), CopyMethod::Program);
        assert!(programs_cover_dst(&[prog], &dst_sizes(&packed)));
        // Swap programs cover like strided programs: per-leaf swap runs
        // into un-padded SoA write every byte.
        use crate::mapping::Byteswap;
        let prog = CopyProgram::compile(&Byteswap::new(AoS::packed(&d, dims.clone())), &soa);
        assert_eq!(prog.method(), CopyMethod::SwapProgram);
        assert!(programs_cover_dst(&[prog], &dst_sizes(&soa)));
        // Gather programs never prove coverage.
        use crate::array::MortonCurve;
        let dims2 = ArrayDims::from([8, 8]);
        let morton = AoS::with_linearizer(&d, dims2.clone(), MortonCurve, true);
        let soa2 = SoA::multi_blob(&d, dims2);
        let prog = CopyProgram::compile(&morton, &soa2);
        assert_eq!(prog.method(), CopyMethod::FieldWise);
        assert!(!programs_cover_dst(&[prog], &dst_sizes(&soa2)));
    }

    #[test]
    fn coverage_proof_rejects_overflowing_spans() {
        // Untrusted op lists (a corrupt program, a forged wire message)
        // must never prove coverage through wrapping span arithmetic —
        // each case below produced a small aliased span (and a false
        // `true`) under unchecked `+`/`*`.
        //
        // Dense strided form: count * elem wraps to 16.
        let p = CopyProgram {
            count: 4,
            dst_count: 4,
            method: CopyMethod::Program,
            ops: vec![CopyOp::StridedRun {
                src_blob: 0,
                src_off: 0,
                src_stride: 16,
                dst_blob: 0,
                dst_off: 0,
                dst_stride: 16,
                elem: 16,
                count: usize::MAX / 16 + 2,
            }],
        };
        assert!(!programs_cover_dst(&[p], &[16]));
        // Memcpy: dst_off + len wraps past zero behind a legit first
        // span.
        let p = CopyProgram {
            count: 1,
            dst_count: 1,
            method: CopyMethod::Blobwise,
            ops: vec![
                CopyOp::Memcpy { src_blob: 0, src_off: 0, dst_blob: 0, dst_off: 0, len: 1 },
                CopyOp::Memcpy {
                    src_blob: 0,
                    src_off: 0,
                    dst_blob: 0,
                    dst_off: 1,
                    len: usize::MAX,
                },
            ],
        };
        assert!(!programs_cover_dst(&[p], &[1]));
        // Interleaved family whose pieces tile the stride but whose
        // full-period span r0 + count * stride wraps to a small end.
        let run = |off: usize| CopyOp::StridedRun {
            src_blob: 0,
            src_off: 0,
            src_stride: 8,
            dst_blob: 0,
            dst_off: off,
            dst_stride: 8,
            elem: 4,
            count: usize::MAX / 8 + 2,
        };
        let p = CopyProgram {
            count: 2,
            dst_count: 2,
            method: CopyMethod::Program,
            ops: vec![run(0), run(4)],
        };
        assert!(!programs_cover_dst(&[p], &[8]));
    }

    #[test]
    fn coverage_proof_holds_across_sharded_program_lists() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096);
        let soa = SoA::multi_blob(&d, dims.clone());
        let progs = shard_programs(&AoSoA::new(&d, dims.clone(), 16), &soa, 7);
        assert!(progs.len() > 1);
        assert!(programs_cover_dst(&progs, &dst_sizes(&soa)));
        // Any single shard alone covers only its slice.
        assert!(!programs_cover_dst(&progs[..1], &dst_sizes(&soa)));
    }

    #[test]
    fn with_parallel_programs_shares_cache_accounting() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let cache = ProgramCache::new();
        let src_m = SoA::multi_blob(&d, dims.clone());
        let dst_m = AoSoA::new(&d, dims.clone(), 16);
        let n1 = cache.with_parallel_programs(&src_m, &dst_m, Some(3), |p| p.len());
        let n2 = cache.with_parallel_programs(&src_m, &dst_m, Some(3), |p| p.len());
        assert_eq!(n1, n2);
        assert_eq!(cache.entries(), 1);
        assert_eq!(cache.hits(), 1);
        // The same (pair, threads) key serves copy_parallel too.
        let mut src = alloc_view(src_m);
        fill_distinct(&mut src);
        let mut dst = alloc_view(dst_m);
        cache.copy_parallel(&src, &mut dst, Some(3));
        assert_eq!(cache.entries(), 1);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn program_cache_is_send_and_sync() {
        // Compile-time contract: one ProgramCache is shared by every
        // store in a serving fleet, across reader + migration threads.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ProgramCache>();
        assert_send_sync::<std::sync::Arc<ProgramCache>>();
    }

    #[test]
    fn program_cache_shared_across_threads_compiles_once() {
        let d = particle_dim();
        let dims = ArrayDims::linear(64);
        let cache = ProgramCache::new();
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut oracle = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        copy_naive(&src, &mut oracle);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 8));
                    cache.copy(&src, &mut dst);
                    assert_eq!(dst.blobs(), oracle.blobs());
                });
            }
        });
        // Racing first-compilers may each compile, but the map holds
        // exactly one entry for the pair afterwards.
        assert_eq!(cache.entries(), 1);
    }

    /// Naive slice oracle: field-wise two-index copy, the reference
    /// for every `compile_slice` strategy.
    fn slice_oracle<MS: Mapping, MD: Mapping>(
        src: &crate::view::View<MS, Vec<u8>>,
        dst: &mut crate::view::View<MD, Vec<u8>>,
        src_start: usize,
        dst_start: usize,
        len: usize,
    ) {
        let info = src.mapping().info().clone();
        for i in 0..len {
            for leaf in 0..info.leaf_count() {
                crate::copy::naive::copy_field_between(
                    src,
                    dst,
                    leaf,
                    src_start + i,
                    dst_start + i,
                    info.fields[leaf].size(),
                );
            }
        }
    }

    /// Differential slice helper: compile_slice must be bit-identical
    /// to the two-index naive oracle, and report the expected method.
    fn check_slice<MS: Mapping + Clone, MD: Mapping + Clone>(
        src_m: MS,
        dst_m: MD,
        src_start: usize,
        dst_start: usize,
        len: usize,
        expect: CopyMethod,
    ) {
        let mut src = alloc_view(src_m);
        fill_distinct(&mut src);
        let mut oracle = alloc_view(dst_m.clone());
        let mut got = alloc_view(dst_m.clone());
        // Sentinel the destinations identically so untouched bytes
        // must match too (the slice writes only its records).
        for v in [&mut oracle, &mut got] {
            let (_, blobs) = v.mapping_and_blobs_mut();
            for b in blobs {
                b.iter_mut().enumerate().for_each(|(i, x)| *x = (i % 251) as u8);
            }
        }
        slice_oracle(&src, &mut oracle, src_start, dst_start, len);
        let prog = CopyProgram::compile_slice(src.mapping(), &dst_m, src_start, dst_start, len);
        assert_eq!(prog.method(), expect, "slice strategy");
        assert_eq!(prog.count(), src.count());
        assert_eq!(prog.dst_count(), oracle.count());
        prog.execute(&src, &mut got);
        assert_eq!(got.blobs(), oracle.blobs(), "slice program != naive oracle");
    }

    #[test]
    fn slice_programs_match_the_two_index_oracle() {
        let d = particle_dim();
        let big = ArrayDims::linear(37);
        let small = ArrayDims::linear(11);
        // Chunked pair, lane-unaligned offsets on both sides.
        check_slice(
            AoSoA::new(&d, big.clone(), 8),
            AoSoA::new(&d, small.clone(), 4),
            13,
            3,
            7,
            CopyMethod::AoSoAChunked,
        );
        // Packed AoS → packed AoS at shifted offsets coalesces to one
        // span per slice (chunk lanes 1).
        check_slice(
            AoS::packed(&d, big.clone()),
            AoS::packed(&d, small.clone()),
            20,
            1,
            9,
            CopyMethod::AoSoAChunked,
        );
        // Affine pair (SoA → aligned AoS): per-leaf strided runs.
        check_slice(
            SoA::multi_blob(&d, big.clone()),
            AoS::aligned(&d, small.clone()),
            5,
            2,
            6,
            CopyMethod::Program,
        );
        // Swap pair: byteswapped source into native SoA.
        use crate::mapping::Byteswap;
        check_slice(
            Byteswap::new(AoS::packed(&d, big.clone())),
            SoA::multi_blob(&d, small.clone()),
            7,
            0,
            11,
            CopyMethod::SwapProgram,
        );
        // Generic side (Morton curve): the element gather fallback.
        use crate::array::MortonCurve;
        check_slice(
            AoS::with_linearizer(&d, ArrayDims::from([8, 8]), MortonCurve, true),
            AoS::packed(&d, small),
            9,
            1,
            8,
            CopyMethod::FieldWise,
        );
    }

    #[test]
    fn slice_with_equal_spaces_and_offsets_matches_range_compile() {
        // A whole-space slice at offset 0 produces the same ops as the
        // range compiler (Blobwise aside, which slices never use).
        let d = particle_dim();
        let dims = ArrayDims::linear(29);
        let src_m = AoSoA::new(&d, dims.clone(), 8);
        let dst_m = SoA::multi_blob(&d, dims.clone());
        let slice = CopyProgram::compile_slice(&src_m, &dst_m, 0, 0, 29);
        let range = CopyProgram::compile(&src_m, &dst_m);
        assert_eq!(slice.ops(), range.ops());
        assert_eq!(slice.method(), range.method());
    }

    #[test]
    fn empty_slice_compiles_to_no_ops() {
        let d = particle_dim();
        let prog = CopyProgram::compile_slice(
            &AoS::packed(&d, ArrayDims::linear(10)),
            &SoA::multi_blob(&d, ArrayDims::linear(4)),
            10,
            4,
            0,
        );
        assert!(prog.ops().is_empty());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_out_of_bounds_rejected() {
        let d = particle_dim();
        let _ = CopyProgram::compile_slice(
            &AoS::packed(&d, ArrayDims::linear(10)),
            &AoS::packed(&d, ArrayDims::linear(4)),
            8,
            0,
            3, // src 8+3 > 10
        );
    }

    #[test]
    #[should_panic(expected = "different record dimensions")]
    fn slice_record_mismatch_rejected() {
        let _ = CopyProgram::compile_slice(
            &AoS::packed(&xy(), ArrayDims::linear(4)),
            &AoS::packed(&particle_dim(), ArrayDims::linear(4)),
            0,
            0,
            2,
        );
    }

    #[test]
    fn empty_extent_compiles_to_no_range_ops() {
        let dims = ArrayDims::linear(0);
        let prog = CopyProgram::compile(
            &AoS::packed(&xy(), dims.clone()),
            &SoA::multi_blob(&xy(), dims),
        );
        assert!(prog.ops().is_empty());
    }

    #[test]
    #[should_panic(expected = "different data spaces")]
    fn mismatched_extents_rejected() {
        let _ = CopyProgram::compile(
            &AoS::packed(&xy(), ArrayDims::linear(3)),
            &AoS::packed(&xy(), ArrayDims::linear(4)),
        );
    }
}
