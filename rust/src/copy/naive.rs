//! Field-wise naive copy (paper §4.2: "The naive copy consists of
//! nested loops over the array and record dimensions and copies
//! field-wise").

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::View;

/// Copy one leaf value between raw blob storage.
#[inline]
pub(crate) fn copy_field<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    leaf: usize,
    lin: usize,
    size: usize,
) where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    copy_field_between(src, dst, leaf, lin, lin, size);
}

/// Copy one leaf value between *different* linearized indices — the
/// gather primitive of slice programs ([`super::CopyProgram::compile_slice`]),
/// where source record `src_lin` lands at destination record `dst_lin`.
#[inline]
pub(crate) fn copy_field_between<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    leaf: usize,
    src_lin: usize,
    dst_lin: usize,
    size: usize,
) where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    let (snr, soff) = src
        .mapping()
        .blob_nr_and_offset(leaf, src.mapping().slot_of_lin(src_lin));
    let src_native = src.mapping().is_native_representation();
    let dst_native = dst.mapping().is_native_representation();
    let (dm, dblobs) = dst.mapping_and_blobs_mut();
    let (dnr, doff) = dm.blob_nr_and_offset(leaf, dm.slot_of_lin(dst_lin));
    let sbytes = &src.blobs()[snr].as_bytes()[soff..soff + size];
    let dbytes = &mut dblobs[dnr].as_bytes_mut()[doff..doff + size];
    dbytes.copy_from_slice(sbytes);
    if src_native != dst_native {
        dbytes.reverse();
    }
}

/// Index-major naive copy: outer loop over array indices, inner loop
/// over record fields (the loop structure the paper identifies as
/// problematic for SoA destinations).
pub fn copy_naive<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>)
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    let info = src.mapping().info().clone();
    let leaves = info.leaf_count();
    let n = src.count();
    for lin in 0..n {
        for leaf in 0..leaves {
            copy_field(src, dst, leaf, lin, info.fields[leaf].size());
        }
    }
}

/// Field-major naive copy: outer loop over record fields, inner loop
/// over array indices — streams each field's region sequentially, which
/// behaves very differently on SoA layouts (paper §4.2 attributes the
/// bad SoA-MB numbers to the index-major structure).
pub fn copy_naive_field_major<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>)
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    let info = src.mapping().info().clone();
    let n = src.count();
    for leaf in 0..info.leaf_count() {
        let size = info.fields[leaf].size();
        for lin in 0..n {
            copy_field(src, dst, leaf, lin, size);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{ArrayDims, MortonCurve, RowMajor};
    use crate::copy::test_support::check_copy;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, One, SoA, Split};
    use crate::record::RecordCoord;

    #[test]
    fn naive_all_layout_pairs() {
        let d = particle_dim();
        let dims = ArrayDims::from([3, 4]);
        // A representative matrix of source/dest layouts.
        macro_rules! pair {
            ($src:expr, $dst:expr) => {
                check_copy($src, $dst, |s, d| copy_naive(s, d));
                check_copy($src, $dst, |s, d| copy_naive_field_major(s, d));
            };
        }
        pair!(AoS::aligned(&d, dims.clone()), SoA::multi_blob(&d, dims.clone()));
        pair!(SoA::multi_blob(&d, dims.clone()), AoS::packed(&d, dims.clone()));
        pair!(AoSoA::new(&d, dims.clone(), 4), SoA::single_blob(&d, dims.clone()));
        pair!(AoS::packed(&d, dims.clone()), AoSoA::new(&d, dims.clone(), 8));
    }

    #[test]
    fn naive_with_morton_and_split() {
        let d = particle_dim();
        let dims = ArrayDims::from([4, 4]);
        check_copy(
            AoS::with_linearizer(&d, dims.clone(), MortonCurve, true),
            SoA::multi_blob(&d, dims.clone()),
            |s, dst| copy_naive(s, dst),
        );
        check_copy(
            SoA::multi_blob(&d, dims.clone()),
            Split::new(
                &d,
                dims.clone(),
                RecordCoord::new(vec![1]),
                |sd, ad| SoA::multi_blob(sd, ad),
                |sd, ad| AoS::aligned(sd, ad),
            ),
            |s, dst| copy_naive(s, dst),
        );
    }

    #[test]
    fn naive_byteswap_both_directions() {
        let d = particle_dim();
        let dims = ArrayDims::linear(6);
        check_copy(
            Byteswap::new(AoS::packed(&d, dims.clone())),
            SoA::multi_blob(&d, dims.clone()),
            |s, dst| copy_naive(s, dst),
        );
        check_copy(
            SoA::multi_blob(&d, dims.clone()),
            Byteswap::new(AoSoA::new(&d, dims.clone(), 2)),
            |s, dst| copy_naive(s, dst),
        );
    }

    #[test]
    fn naive_into_one_collapses() {
        // Copying into a One mapping leaves the last record's values.
        let d = particle_dim();
        let dims = ArrayDims::linear(3);
        let mut src = crate::view::alloc_view(AoS::packed(&d, dims.clone()));
        crate::copy::test_support::fill_distinct(&mut src);
        let mut dst = crate::view::alloc_view(One::new(&d, dims.clone()));
        copy_naive(&src, &mut dst);
        for leaf in 0..8 {
            let (snr, soff) = src.mapping().blob_nr_and_offset(leaf, 2);
            let size = src.mapping().info().fields[leaf].size();
            let sv = &src.blobs()[snr][soff..soff + size];
            let (dnr, doff) = dst.mapping().blob_nr_and_offset(leaf, 0);
            let dv = &dst.blobs()[dnr][doff..doff + size];
            assert_eq!(sv, dv);
        }
    }

    #[test]
    fn rowmajor_generic_matches_specialized() {
        // Verify RowMajor linearizer through the generic constructor
        // agrees with the default.
        let d = particle_dim();
        let dims = ArrayDims::from([2, 5]);
        check_copy(
            AoS::with_linearizer(&d, dims.clone(), RowMajor, false),
            AoS::packed(&d, dims.clone()),
            |s, dst| copy_naive(s, dst),
        );
    }
}
