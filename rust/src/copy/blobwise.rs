//! Per-blob memcpy for identical layouts (paper §3.9: "Copying the
//! contents of a view from one memory region to another if mapping and
//! size are identical is trivial") — a thin wrapper over the program
//! compiler, whose identical-layout strategy emits exactly one
//! [`super::CopyOp::Memcpy`] per blob.

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::View;

/// Copy every blob verbatim. Panics unless the layouts are identical
/// (verify with [`super::layouts_identical`]; the dispatcher does).
pub fn copy_blobwise<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>)
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    assert!(
        super::layouts_identical_with(src.mapping(), dst.mapping(), &sp, &dp),
        "copy_blobwise requires identical layouts: {} vs {}",
        src.mapping().mapping_name(),
        dst.mapping().mapping_name()
    );
    let order = super::ChunkOrder::ReadContiguous;
    let prog = super::program::compile_with(src.mapping(), dst.mapping(), &sp, &dp, order);
    debug_assert_eq!(prog.method(), super::CopyMethod::Blobwise);
    prog.execute(src, dst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::{check_copy, fill_distinct};
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};
    use crate::view::alloc_view;

    #[test]
    fn identical_layouts_roundtrip() {
        let d = particle_dim();
        let dims = ArrayDims::from([4, 4]);
        check_copy(
            SoA::multi_blob(&d, dims.clone()),
            SoA::multi_blob(&d, dims.clone()),
            |s, dst| copy_blobwise(s, dst),
        );
        check_copy(
            AoSoA::new(&d, dims.clone(), 8),
            AoSoA::new(&d, dims.clone(), 8),
            |s, dst| copy_blobwise(s, dst),
        );
    }

    #[test]
    fn byteswapped_pair_is_identical_layout() {
        // Two byteswapped views share representation: raw memcpy is
        // legal and values stay correct.
        let d = particle_dim();
        let dims = ArrayDims::linear(8);
        let mut src = alloc_view(Byteswap::new(AoS::packed(&d, dims.clone())));
        fill_distinct(&mut src);
        let mut dst = alloc_view(Byteswap::new(AoS::packed(&d, dims.clone())));
        copy_blobwise(&src, &mut dst);
        assert!(crate::copy::views_equal(&src, &dst));
    }

    #[test]
    #[should_panic(expected = "identical layouts")]
    fn different_layouts_rejected() {
        let d = particle_dim();
        let dims = ArrayDims::linear(8);
        let src = alloc_view(AoS::packed(&d, dims.clone()));
        let mut dst = alloc_view(AoS::aligned(&d, dims.clone()));
        copy_blobwise(&src, &mut dst);
    }
}
