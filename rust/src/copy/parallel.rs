//! Multi-threaded copy variants — the paper's "(p)" rows in fig 7.
//!
//! The record range is split into contiguous chunks, one per thread.
//! Soundness: distinct linear indices map to disjoint destination byte
//! ranges for every *storage* mapping (the fundamental mapping
//! invariant, property-tested in `rust/tests`), so threads never write
//! the same byte. Aliasing mappings ([`crate::mapping::One`],
//! [`crate::mapping::Null`]) must not be parallel destinations.

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::View;

/// Base pointers + lengths of the destination blobs, shared across the
/// worker threads.
struct DstBlobs {
    ptrs: Vec<(*mut u8, usize)>,
}

// SAFETY: the worker threads write disjoint ranges (see module docs).
unsafe impl Send for DstBlobs {}
unsafe impl Sync for DstBlobs {}

fn worker_ranges(n: usize, threads: usize, align: usize) -> Vec<(usize, usize)> {
    let threads = threads.max(1);
    let per = n.div_ceil(threads);
    // Round chunk boundaries up to `align` so chunked copies stay on
    // lane boundaries where possible.
    let per = per.div_ceil(align) * align;
    let mut out = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + per).min(n);
        out.push((start, end));
        start = end;
    }
    out
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Parallel field-wise copy (paper's "naive copy (p)").
pub fn copy_naive_parallel<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    threads: Option<usize>,
) where
    MS: Mapping,
    MD: Mapping + Sync,
    BS: Blob + Sync,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    let n = src.count();
    let threads = threads.unwrap_or_else(default_threads).min(n.max(1));
    if threads <= 1 || n < 1024 {
        super::copy_naive(src, dst);
        return;
    }
    let info = src.mapping().info().clone();
    let sizes: Vec<usize> = info.fields.iter().map(|f| f.size()).collect();
    let src_native = src.mapping().is_native_representation();
    let dst_native = dst.mapping().is_native_representation();
    let (dmap, dblobs) = dst.mapping_and_blobs_mut();
    let dst_ptrs = DstBlobs {
        ptrs: dblobs
            .iter_mut()
            .map(|b| {
                let s = b.as_bytes_mut();
                (s.as_mut_ptr(), s.len())
            })
            .collect(),
    };
    let ranges = worker_ranges(n, threads, 1);
    std::thread::scope(|scope| {
        for (start, end) in ranges {
            let dst_ptrs = &dst_ptrs;
            let sizes = &sizes;
            scope.spawn(move || {
                for lin in start..end {
                    let sslot = src.mapping().slot_of_lin(lin);
                    let dslot = dmap.slot_of_lin(lin);
                    for (leaf, &size) in sizes.iter().enumerate() {
                        let (snr, soff) = src.mapping().blob_nr_and_offset(leaf, sslot);
                        let (dnr, doff) = dmap.blob_nr_and_offset(leaf, dslot);
                        let sbytes = src.blobs()[snr].as_bytes();
                        let (dptr, dlen) = dst_ptrs.ptrs[dnr];
                        assert!(doff + size <= dlen);
                        // SAFETY: range checked above; disjoint across
                        // threads by the mapping invariant.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                sbytes.as_ptr().add(soff),
                                dptr.add(doff),
                                size,
                            );
                            if src_native != dst_native {
                                std::slice::from_raw_parts_mut(dptr.add(doff), size).reverse();
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Parallel chunked AoSoA-family copy (paper's "aosoa_copy (r/w) (p)").
pub fn copy_aosoa_parallel<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    order: super::ChunkOrder,
    threads: Option<usize>,
) where
    MS: Mapping,
    MD: Mapping + Sync,
    BS: Blob + Sync,
    BD: BlobMut,
{
    debug_assert!(super::aosoa_compatible(src.mapping(), dst.mapping()));
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    let src_lanes = sp.chunk_lanes().expect("source not AoSoA-family");
    let dst_lanes = dp.chunk_lanes().expect("destination not AoSoA-family");
    let n = src.count();
    let threads = threads.unwrap_or_else(default_threads).min(n.max(1));
    if threads <= 1 || n < 1024 {
        super::aosoa::aosoa_copy_with(src, dst, order, &sp, &dp);
        return;
    }
    let info = src.mapping().info().clone();
    let sizes: Vec<usize> = info.fields.iter().map(|f| f.size()).collect();
    let outer_lanes = match order {
        super::ChunkOrder::ReadContiguous => src_lanes,
        super::ChunkOrder::WriteContiguous => dst_lanes,
    };
    let (dmap, dblobs) = dst.mapping_and_blobs_mut();
    let dst_ptrs = DstBlobs {
        ptrs: dblobs
            .iter_mut()
            .map(|b| {
                let s = b.as_bytes_mut();
                (s.as_mut_ptr(), s.len())
            })
            .collect(),
    };
    // Align thread boundaries to the outer lane size (capped to keep
    // the alignment from collapsing the thread count for SoA, where
    // lanes == n).
    let align = outer_lanes.min(n.div_ceil(threads).max(1));
    let ranges = worker_ranges(n, threads, align);
    std::thread::scope(|scope| {
        for (t_start, t_end) in ranges {
            let dst_ptrs = &dst_ptrs;
            let sizes = &sizes;
            let (sp, dp) = (&sp, &dp);
            scope.spawn(move || {
                let leaves = sizes.len();
                let mut block_start = t_start;
                while block_start < t_end {
                    let block_end =
                        (((block_start / outer_lanes) + 1) * outer_lanes).min(t_end);
                    for leaf in 0..leaves {
                        let size = sizes[leaf];
                        let mut pos = block_start;
                        while pos < block_end {
                            let src_run_end = ((pos / src_lanes) + 1) * src_lanes;
                            let dst_run_end = ((pos / dst_lanes) + 1) * dst_lanes;
                            let end = block_end.min(src_run_end).min(dst_run_end);
                            let len = end - pos;
                            let (snr, soff) = sp.resolve_with(src.mapping(), leaf, pos);
                            let (dnr, doff) = dp.resolve_with(dmap, leaf, pos);
                            let nbytes = len * size;
                            let sbytes = src.blobs()[snr].as_bytes();
                            let (dptr, dlen) = dst_ptrs.ptrs[dnr];
                            assert!(doff + nbytes <= dlen && soff + nbytes <= sbytes.len());
                            // SAFETY: checked above; thread ranges are
                            // disjoint in lin, so dst ranges are
                            // disjoint by the mapping invariant.
                            unsafe {
                                std::ptr::copy_nonoverlapping(
                                    sbytes.as_ptr().add(soff),
                                    dptr.add(doff),
                                    nbytes,
                                );
                            }
                            pos = end;
                        }
                    }
                    block_start = block_end;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::fill_distinct;
    use crate::copy::{views_equal, ChunkOrder};
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::alloc_view;

    #[test]
    fn parallel_naive_matches_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(5000);
        let mut src = alloc_view(AoS::aligned(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut dst = alloc_view(SoA::multi_blob(&d, dims.clone()));
        copy_naive_parallel(&src, &mut dst, Some(4));
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn parallel_aosoa_matches_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
            let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 32));
            copy_aosoa_parallel(&src, &mut dst, order, Some(4));
            assert!(views_equal(&src, &dst), "order {order:?}");
        }
    }

    #[test]
    fn small_inputs_fall_back_to_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(10);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 4));
        copy_aosoa_parallel(&src, &mut dst, ChunkOrder::ReadContiguous, Some(8));
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn worker_ranges_cover_everything() {
        for (n, t, a) in [(100, 4, 1), (4096, 8, 32), (5, 8, 4), (1000, 3, 7)] {
            let ranges = super::worker_ranges(n, t, a);
            let mut expect = 0;
            for (s, e) in &ranges {
                assert_eq!(*s, expect);
                assert!(e > s);
                expect = *e;
            }
            assert_eq!(expect, n);
        }
    }

    #[test]
    fn single_thread_option() {
        let d = particle_dim();
        let dims = ArrayDims::linear(2048);
        let mut src = alloc_view(AoSoA::new(&d, dims.clone(), 16), );
        fill_distinct(&mut src);
        let mut dst = alloc_view(SoA::single_blob(&d, dims.clone()));
        copy_aosoa_parallel(&src, &mut dst, ChunkOrder::WriteContiguous, Some(1));
        assert!(views_equal(&src, &dst));
    }
}
