//! Multi-threaded copy variants — the paper's "(p)" rows in fig 7.
//!
//! The record range is split into contiguous shards by the shared
//! plan-aligned splitter ([`crate::view::shard`]): `shard_range` for
//! the field-wise copy; the chunked copy compiles one
//! [`super::program::CopyProgram`] per `shard_pair` shard (the lcm of
//! both plans' lane-block alignments), so thread boundaries never
//! straddle an AoSoA lane block on either side. Soundness:
//! distinct linear indices map to disjoint destination byte ranges for
//! every *storage* mapping (the fundamental mapping invariant,
//! property-tested in `rust/tests`), so threads never write the same
//! byte. Aliasing mappings ([`crate::mapping::One`],
//! [`crate::mapping::Null`]) must not be parallel destinations.

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::shard::shard_range;
use crate::view::View;

/// Base pointers + lengths of the destination blobs, shared across the
/// worker threads.
struct DstBlobs {
    ptrs: Vec<(*mut u8, usize)>,
}

// SAFETY: the worker threads write disjoint ranges (see module docs).
unsafe impl Send for DstBlobs {}
unsafe impl Sync for DstBlobs {}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Parallel field-wise copy (paper's "naive copy (p)").
pub fn copy_naive_parallel<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    threads: Option<usize>,
) where
    MS: Mapping,
    MD: Mapping + Sync,
    BS: Blob + Sync,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    let n = src.count();
    let threads = threads.unwrap_or_else(default_threads).min(n.max(1));
    if threads <= 1 || n < 1024 {
        super::copy_naive(src, dst);
        return;
    }
    let info = src.mapping().info().clone();
    let sizes: Vec<usize> = info.fields.iter().map(|f| f.size()).collect();
    let src_native = src.mapping().is_native_representation();
    let dst_native = dst.mapping().is_native_representation();
    let (dmap, dblobs) = dst.mapping_and_blobs_mut();
    let dst_ptrs = DstBlobs {
        ptrs: dblobs
            .iter_mut()
            .map(|b| {
                let s = b.as_bytes_mut();
                (s.as_mut_ptr(), s.len())
            })
            .collect(),
    };
    let ranges = shard_range(n, threads, 1);
    std::thread::scope(|scope| {
        for sh in ranges {
            let dst_ptrs = &dst_ptrs;
            let sizes = &sizes;
            scope.spawn(move || {
                for lin in sh.start..sh.end {
                    let sslot = src.mapping().slot_of_lin(lin);
                    let dslot = dmap.slot_of_lin(lin);
                    for (leaf, &size) in sizes.iter().enumerate() {
                        let (snr, soff) = src.mapping().blob_nr_and_offset(leaf, sslot);
                        let (dnr, doff) = dmap.blob_nr_and_offset(leaf, dslot);
                        let sbytes = src.blobs()[snr].as_bytes();
                        let (dptr, dlen) = dst_ptrs.ptrs[dnr];
                        assert!(doff + size <= dlen);
                        // SAFETY: range checked above; disjoint across
                        // threads by the mapping invariant.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                sbytes.as_ptr().add(soff),
                                dptr.add(doff),
                                size,
                            );
                            if src_native != dst_native {
                                std::slice::from_raw_parts_mut(dptr.add(doff), size).reverse();
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Parallel chunked AoSoA-family copy (paper's "aosoa_copy (r/w) (p)"):
/// a thin wrapper over the program compiler — one sub-program per
/// plan-aligned shard, executed on scoped threads. The bespoke chunk
/// traversal that used to live here is now
/// [`super::program::compile_range_with`] run once per shard.
pub fn copy_aosoa_parallel<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    order: super::ChunkOrder,
    threads: Option<usize>,
) where
    MS: Mapping,
    MD: Mapping + Sync,
    BS: Blob + Sync,
    BD: BlobMut,
{
    debug_assert!(super::aosoa_compatible(src.mapping(), dst.mapping()));
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    sp.chunk_lanes().expect("source not AoSoA-family");
    dp.chunk_lanes().expect("destination not AoSoA-family");
    super::program::run_parallel_with(src, dst, &sp, &dp, order, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::fill_distinct;
    use crate::copy::{views_equal, ChunkOrder};
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::alloc_view;

    #[test]
    fn parallel_naive_matches_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(5000);
        let mut src = alloc_view(AoS::aligned(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut dst = alloc_view(SoA::multi_blob(&d, dims.clone()));
        copy_naive_parallel(&src, &mut dst, Some(4));
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn parallel_aosoa_matches_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
            let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 32));
            copy_aosoa_parallel(&src, &mut dst, order, Some(4));
            assert!(views_equal(&src, &dst), "order {order:?}");
        }
    }

    #[test]
    fn small_inputs_fall_back_to_serial() {
        let d = particle_dim();
        let dims = ArrayDims::linear(10);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 4));
        copy_aosoa_parallel(&src, &mut dst, ChunkOrder::ReadContiguous, Some(8));
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn thread_boundaries_respect_both_layouts() {
        // SoA (whole-array runs) x AoSoA32: boundaries must be 32-lane
        // multiples — the old cap could produce arbitrary splits here.
        let d = particle_dim();
        let sp = SoA::multi_blob(&d, ArrayDims::linear(4096 + 17)).plan();
        let dp = AoSoA::new(&d, ArrayDims::linear(4096 + 17), 32).plan();
        let align = crate::view::shard::pair_align(&sp, &dp);
        assert_eq!(align, 32);
        for sh in shard_range(4096 + 17, 4, align) {
            assert_eq!(sh.start % 32, 0);
        }
    }

    #[test]
    fn single_thread_option() {
        let d = particle_dim();
        let dims = ArrayDims::linear(2048);
        let mut src = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        fill_distinct(&mut src);
        let mut dst = alloc_view(SoA::single_blob(&d, dims.clone()));
        copy_aosoa_parallel(&src, &mut dst, ChunkOrder::WriteContiguous, Some(1));
        assert!(views_equal(&src, &dst));
    }
}
