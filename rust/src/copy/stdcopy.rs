//! Iterator-driven element copy — the paper's `std::copy` variant
//! (§4.2): uses the view's record iterator, so each element access pays
//! the 1-D → N-D → mapping round trip, which the paper measures as
//! slightly slower than the naive nested loops in most cases.

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::View;

/// Copy via record iterators: for each record ref yielded by the source
/// iterator, delinearize to an N-d index and copy all leaves through
/// the N-d access path.
pub fn copy_stdcopy<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>)
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    let info = src.mapping().info().clone();
    let dims = src.mapping().dims().clone();
    let leaves = info.leaf_count();
    for rec in src {
        let lin = rec.lin();
        // The iterator models a 1-D sequence; mapping back to the array
        // dimensions (later re-linearized by each mapping) is exactly
        // the overhead the paper attributes to this variant.
        let idx = dims.delinearize_row_major(lin);
        for leaf in 0..leaves {
            let size = info.fields[leaf].size();
            let sslot = src.mapping().slot_of_nd(&idx);
            let (snr, soff) = src.mapping().blob_nr_and_offset(leaf, sslot);
            let src_native = src.mapping().is_native_representation();
            let dst_native = dst.mapping().is_native_representation();
            let (dm, dblobs) = dst.mapping_and_blobs_mut();
            let dslot = dm.slot_of_nd(&idx);
            let (dnr, doff) = dm.blob_nr_and_offset(leaf, dslot);
            let sbytes = &src.blobs()[snr].as_bytes()[soff..soff + size];
            let dbytes = &mut dblobs[dnr].as_bytes_mut()[doff..doff + size];
            dbytes.copy_from_slice(sbytes);
            if src_native != dst_native {
                dbytes.reverse();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::check_copy;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA};

    #[test]
    fn stdcopy_layout_pairs() {
        let d = particle_dim();
        let dims = ArrayDims::from([2, 3, 2]);
        check_copy(
            AoS::aligned(&d, dims.clone()),
            SoA::multi_blob(&d, dims.clone()),
            |s, dst| copy_stdcopy(s, dst),
        );
        check_copy(
            SoA::single_blob(&d, dims.clone()),
            AoSoA::new(&d, dims.clone(), 4),
            |s, dst| copy_stdcopy(s, dst),
        );
    }

    #[test]
    fn stdcopy_matches_naive() {
        let d = particle_dim();
        let dims = ArrayDims::from([3, 3]);
        let mut src = crate::view::alloc_view(AoS::packed(&d, dims.clone()));
        crate::copy::test_support::fill_distinct(&mut src);
        let mut a = crate::view::alloc_view(SoA::multi_blob(&d, dims.clone()));
        let mut b = crate::view::alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy_naive(&src, &mut a);
        copy_stdcopy(&src, &mut b);
        assert_eq!(a.blobs(), b.blobs());
    }
}
