//! Layout-aware copying between views (paper §3.9 / §4.2, fig 7).
//!
//! Copying between two views of the *same data space* but different
//! mappings cannot be a plain memcpy; the fallback is a field-wise copy.
//! But mappings encapsulate full layout knowledge, so LLAMA provides
//! specialized routines that move data in the largest contiguous chunks
//! both layouts admit:
//!
//! * [`blobwise::copy_blobwise`] — per-blob memcpy when the layouts are
//!   identical.
//! * [`aosoa::aosoa_copy`] — chunked copy between any two AoSoA-family
//!   layouts (packed AoS = 1 lane, AoSoA-L, SoA = N lanes), in
//!   read-contiguous or write-contiguous traversal.
//! * [`naive::copy_naive`] — field-wise nested-loop fallback.
//! * [`stdcopy::copy_stdcopy`] — iterator-driven element copy, the
//!   paper's `std::copy` analogue.
//! * [`parallel`] — multi-threaded versions of naive and aosoa.
//!
//! [`copy`] dispatches to the best applicable strategy, like the paper's
//! `llama::copy`.

pub mod aosoa;
pub mod blobwise;
pub mod naive;
pub mod parallel;
pub mod stdcopy;

use crate::blob::{Blob, BlobMut};
use crate::mapping::{AddrPlan, LayoutPlan, Mapping};
use crate::view::View;

pub use aosoa::{aosoa_copy, ChunkOrder};
pub use blobwise::copy_blobwise;
pub use naive::{copy_naive, copy_naive_field_major};
pub use parallel::{copy_aosoa_parallel, copy_naive_parallel};
pub use stdcopy::copy_stdcopy;

/// Which strategy [`copy`] selected (returned for tests/reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyMethod {
    Blobwise,
    AoSoAChunked,
    FieldWise,
}

/// True if `src` and `dst` describe the same data space: identical
/// record dimensions and array extents.
pub fn same_data_space<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(src: &MS, dst: &MD) -> bool {
    src.info().dim == dst.info().dim && src.dims() == dst.dims()
}

/// True if the two mappings produce byte-identical layouts (so a
/// per-blob memcpy is valid): same data space, same blob shapes, and
/// either equal non-generic [`LayoutPlan`]s (the plan fully determines
/// the byte placement) or — for generic plans, where the closed form is
/// unavailable — the same mapping identity.
pub fn layouts_identical<MS: Mapping, MD: Mapping>(src: &MS, dst: &MD) -> bool {
    layouts_identical_with(src, dst, &src.plan(), &dst.plan())
}

/// [`layouts_identical`] over plans the caller already compiled.
pub(crate) fn layouts_identical_with<MS: Mapping, MD: Mapping>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
) -> bool {
    if !(same_data_space(src, dst)
        && src.blob_count() == dst.blob_count()
        && (0..src.blob_count()).all(|b| src.blob_size(b) == dst.blob_size(b))
        && sp.native() == dp.native())
    {
        return false;
    }
    // Closed-form plans fully determine byte placement and are
    // authoritative — equal names must not override a plan mismatch.
    // Only generic plans (no closed form to compare) fall back to
    // mapping identity by name.
    let closed_form =
        !matches!(sp.addr(), AddrPlan::Generic) && !matches!(dp.addr(), AddrPlan::Generic);
    if closed_form {
        sp == dp
    } else {
        src.mapping_name() == dst.mapping_name()
    }
}

/// True if both plans admit the chunked copy: native representation on
/// both sides and an AoSoA-family lane count each (packed AoS = 1,
/// AoSoA-L = L, SoA = count).
pub fn plans_chunk_compatible(src: &LayoutPlan, dst: &LayoutPlan) -> bool {
    src.native() && dst.native() && src.chunk_lanes().is_some() && dst.chunk_lanes().is_some()
}

/// True if both mappings are in the AoSoA family with native
/// representation, enabling the chunked copy.
pub fn aosoa_compatible<MS: Mapping, MD: Mapping>(src: &MS, dst: &MD) -> bool {
    same_data_space(src, dst) && plans_chunk_compatible(&src.plan(), &dst.plan())
}

/// Layout-aware copy dispatcher (the paper's `llama::copy`): compiles
/// both mappings into [`LayoutPlan`]s, compares them to pick the
/// fastest applicable strategy, and returns which one ran.
///
/// Panics if the views do not share a data space.
pub fn copy<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>) -> CopyMethod
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    assert!(
        same_data_space(src.mapping(), dst.mapping()),
        "copy between different data spaces: {} vs {}",
        src.mapping().mapping_name(),
        dst.mapping().mapping_name()
    );
    // Compile each side exactly once; every strategy below consumes the
    // same two plans.
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    if layouts_identical_with(src.mapping(), dst.mapping(), &sp, &dp) {
        blobwise::copy_blobwise_prechecked(src, dst);
        CopyMethod::Blobwise
    } else if plans_chunk_compatible(&sp, &dp) {
        aosoa::aosoa_copy_with(src, dst, ChunkOrder::ReadContiguous, &sp, &dp);
        CopyMethod::AoSoAChunked
    } else {
        copy_naive(src, dst);
        CopyMethod::FieldWise
    }
}

/// Field-wise equality of two views over the same data space (test
/// helper and verification step for the benchmarks).
pub fn views_equal<MS, MD, BS, BD>(a: &View<MS, BS>, b: &View<MD, BD>) -> bool
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: Blob,
{
    if !same_data_space(a.mapping(), b.mapping()) {
        return false;
    }
    let info = a.mapping().info().clone();
    for lin in 0..a.count() {
        for leaf in 0..info.leaf_count() {
            let (anr, aoff) = a
                .mapping()
                .blob_nr_and_offset(leaf, a.mapping().slot_of_lin(lin));
            let (bnr, boff) = b
                .mapping()
                .blob_nr_and_offset(leaf, b.mapping().slot_of_lin(lin));
            let size = info.fields[leaf].size();
            let mut av = a.blobs()[anr].as_bytes()[aoff..aoff + size].to_vec();
            let mut bv = b.blobs()[bnr].as_bytes()[boff..boff + size].to_vec();
            if !a.mapping().is_native_representation() {
                av.reverse();
            }
            if !b.mapping().is_native_representation() {
                bv.reverse();
            }
            if av != bv {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Fill every field of a view with a value derived from (leaf, lin)
    /// so cross-talk is detectable.
    pub fn fill_distinct<M: Mapping, B: BlobMut>(v: &mut View<M, B>) {
        use crate::record::Scalar;
        let info = v.mapping().info().clone();
        for lin in 0..v.count() {
            for leaf in 0..info.leaf_count() {
                let seed = (leaf * 131 + lin * 7 + 3) % 251;
                match info.fields[leaf].scalar {
                    Scalar::F32 => v.set::<f32>(lin, leaf, seed as f32 * 0.5),
                    Scalar::F64 => v.set::<f64>(lin, leaf, seed as f64 * 0.25),
                    Scalar::I8 => v.set::<i8>(lin, leaf, seed as i8),
                    Scalar::I16 => v.set::<i16>(lin, leaf, seed as i16),
                    Scalar::I32 => v.set::<i32>(lin, leaf, seed as i32),
                    Scalar::I64 => v.set::<i64>(lin, leaf, seed as i64),
                    Scalar::U8 => v.set::<u8>(lin, leaf, seed as u8),
                    Scalar::U16 => v.set::<u16>(lin, leaf, seed as u16),
                    Scalar::U32 => v.set::<u32>(lin, leaf, seed as u32),
                    Scalar::U64 => v.set::<u64>(lin, leaf, seed as u64),
                    Scalar::Bool => v.set::<bool>(lin, leaf, seed % 2 == 0),
                }
            }
        }
    }

    /// Assert a freshly-allocated destination receives exactly the
    /// source contents under `copy_fn`.
    pub fn check_copy<MS, MD>(
        src_mapping: MS,
        dst_mapping: MD,
        copy_fn: impl FnOnce(&View<MS, Vec<u8>>, &mut View<MD, Vec<u8>>),
    ) where
        MS: Mapping,
        MD: Mapping,
    {
        let mut src = crate::view::alloc_view(src_mapping);
        let mut dst = crate::view::alloc_view(dst_mapping);
        fill_distinct(&mut src);
        copy_fn(&src, &mut dst);
        assert!(
            views_equal(&src, &dst),
            "copy mismatch {} -> {}",
            src.mapping().mapping_name(),
            dst.mapping().mapping_name()
        );
    }

    #[allow(dead_code)]
    pub fn read_f32<M: Mapping, B: Blob>(v: &View<M, B>, lin: usize, leaf: usize) -> f32 {
        v.get::<f32>(lin, leaf)
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};
    use crate::view::alloc_view;

    #[test]
    fn dispatcher_picks_blobwise_for_identical() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::Blobwise);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn dispatcher_picks_chunked_for_aosoa_family() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(AoSoA::new(&d, ArrayDims::linear(16), 4));
        assert_eq!(copy(&src, &mut dst), CopyMethod::AoSoAChunked);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn dispatcher_falls_back_to_fieldwise() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        // Aligned AoS is not in the chunkable family.
        let mut dst = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(16)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::FieldWise);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn byteswap_forces_fieldwise_and_stays_correct() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(Byteswap::new(SoA::multi_blob(&d, ArrayDims::linear(8))));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(8)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::FieldWise);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    #[should_panic(expected = "different data spaces")]
    fn mismatched_extents_panic() {
        let d = particle_dim();
        let src = alloc_view(AoS::aligned(&d, ArrayDims::linear(8)));
        let mut dst = alloc_view(AoS::aligned(&d, ArrayDims::linear(9)));
        let _ = copy(&src, &mut dst);
    }
}
