//! Layout-aware copying between views (paper §3.9 / §4.2, fig 7).
//!
//! Copying between two views of the *same data space* but different
//! mappings cannot be a plain memcpy; the fallback is a field-wise copy.
//! But mappings encapsulate full layout knowledge, so LLAMA provides
//! specialized routines that move data in the largest contiguous chunks
//! both layouts admit:
//!
//! * [`blobwise::copy_blobwise`] — per-blob memcpy when the layouts are
//!   identical.
//! * [`aosoa::aosoa_copy`] — chunked copy between any two AoSoA-family
//!   layouts (packed AoS = 1 lane, AoSoA-L, SoA = N lanes), in
//!   read-contiguous or write-contiguous traversal.
//! * [`naive::copy_naive`] — field-wise nested-loop fallback (and the
//!   differential oracle the program compiler is tested against).
//! * [`stdcopy::copy_stdcopy`] — iterator-driven element copy, the
//!   paper's `std::copy` analogue.
//! * [`parallel`] — multi-threaded versions of naive and aosoa.
//! * [`program`] — the (src plan, dst plan) pair compiled **once** into
//!   an executable [`program::CopyProgram`]: span-merged memcpys,
//!   strided runs, per-element swap runs, or a gather fallback.
//!   `blobwise` and `aosoa` are thin wrappers over this compiler.
//! * [`wire`] — serialization over process boundaries as a compiled
//!   copy: pack into (and unpack from) a self-describing dense wire
//!   buffer, with cross-endian peers served by swap-run programs.
//!
//! [`copy`] (and [`copy_parallel`]) compile the pair into a program and
//! execute it, like the paper's `llama::copy`.

pub mod aosoa;
pub mod blobwise;
pub mod naive;
pub mod parallel;
pub mod program;
pub mod stdcopy;
pub mod wire;

use crate::blob::{Blob, BlobMut};
use crate::mapping::{AddrPlan, LayoutPlan, Mapping};
use crate::view::View;

pub use aosoa::{aosoa_copy, ChunkOrder};
pub use blobwise::copy_blobwise;
pub use naive::{copy_naive, copy_naive_field_major};
pub use parallel::{copy_aosoa_parallel, copy_naive_parallel};
pub use program::{
    execute_parallel, execute_parallel_with, programs_cover_dst, CopyOp, CopyProgram, ProgramCache,
};
pub use stdcopy::copy_stdcopy;
pub use wire::{
    deserialize, deserialize_into, deserialize_range_into, deserialize_range_into_at,
    deserialize_sharded_into, read_message, serialize, serialize_endian, serialize_range,
    serialize_range_endian, serialize_range_with, serialize_sharded, serialize_with, wire_view,
    write_message, write_range_chunked, WireMessage, CHUNK_MAGIC, MAX_HEADER_BYTES,
};

/// Which strategy the compiled program uses (returned by [`copy`] /
/// [`copy_parallel`] for tests and reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyMethod {
    /// Identical layouts: one memcpy per blob.
    Blobwise,
    /// Both sides AoSoA-family: span-merged chunk runs.
    AoSoAChunked,
    /// Both sides affine (outside the chunkable family): strided-run
    /// program — pairs that were field-wise before the compiler.
    Program,
    /// Affine pair with exactly one byteswapped side: per-leaf swapping
    /// strided runs ([`CopyOp::SwapRun`]) — the cross-endian pack and
    /// unpack path of `copy::wire`, field-wise before the compiler.
    SwapProgram,
    /// Generic addressing on either side (or a representation mismatch
    /// outside the affine closed form): element gather through the
    /// mappings.
    FieldWise,
}

/// True if `src` and `dst` describe the same data space: identical
/// record dimensions and array extents.
pub fn same_data_space<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(src: &MS, dst: &MD) -> bool {
    src.info().dim == dst.info().dim && src.dims() == dst.dims()
}

/// True if the two mappings produce byte-identical layouts (so a
/// per-blob memcpy is valid): same data space, same blob shapes, and
/// either equal non-generic [`LayoutPlan`]s (the plan fully determines
/// the byte placement) or — for generic plans, where the closed form is
/// unavailable — the same mapping identity.
pub fn layouts_identical<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(src: &MS, dst: &MD) -> bool {
    layouts_identical_with(src, dst, &src.plan(), &dst.plan())
}

/// [`layouts_identical`] over plans the caller already compiled.
pub(crate) fn layouts_identical_with<MS: Mapping + ?Sized, MD: Mapping + ?Sized>(
    src: &MS,
    dst: &MD,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
) -> bool {
    if !(same_data_space(src, dst)
        && src.blob_count() == dst.blob_count()
        && (0..src.blob_count()).all(|b| src.blob_size(b) == dst.blob_size(b))
        && sp.native() == dp.native())
    {
        return false;
    }
    // Closed-form plans fully determine byte placement and are
    // authoritative — equal names must not override a plan mismatch.
    // Only generic plans (no closed form to compare) fall back to
    // mapping identity by name.
    let closed_form =
        !matches!(sp.addr(), AddrPlan::Generic) && !matches!(dp.addr(), AddrPlan::Generic);
    if closed_form {
        sp == dp
    } else {
        src.mapping_name() == dst.mapping_name()
    }
}

/// True if both plans admit the chunked copy: *equal* byte
/// representation on both sides (both native, or both byteswapped —
/// equal-representation bytes move verbatim, no swap needed) and an
/// AoSoA-family lane count each (packed AoS = 1, AoSoA-L = L,
/// SoA = count).
pub fn plans_chunk_compatible(src: &LayoutPlan, dst: &LayoutPlan) -> bool {
    src.native() == dst.native() && src.chunk_lanes().is_some() && dst.chunk_lanes().is_some()
}

/// True if both plans admit the strided-run program: affine addressing
/// with *equal* byte representation on both sides — the pairs outside
/// the chunkable family that still compile to a verbatim closed form
/// (checked *after* [`plans_chunk_compatible`] by the program
/// compiler).
pub fn plans_strided_compatible(src: &LayoutPlan, dst: &LayoutPlan) -> bool {
    src.native() == dst.native()
        && matches!(src.addr(), AddrPlan::Affine(_))
        && matches!(dst.addr(), AddrPlan::Affine(_))
}

/// True if both plans are affine but the byte representation
/// *mismatches* (exactly one side byteswapped): every leaf compiles to
/// a per-element byte-reversing [`CopyOp::SwapRun`] instead of the
/// element gather. Checked after the verbatim strategies by the
/// program compiler — serialization's cross-endian pack/unpack path.
pub fn plans_swap_compatible(src: &LayoutPlan, dst: &LayoutPlan) -> bool {
    src.native() != dst.native()
        && matches!(src.addr(), AddrPlan::Affine(_))
        && matches!(dst.addr(), AddrPlan::Affine(_))
}

/// True if both mappings are in the AoSoA family with equal byte
/// representation, enabling the chunked copy.
pub fn aosoa_compatible<MS: Mapping, MD: Mapping>(src: &MS, dst: &MD) -> bool {
    same_data_space(src, dst) && plans_chunk_compatible(&src.plan(), &dst.plan())
}

/// Layout-aware copy dispatcher (the paper's `llama::copy`): compiles
/// both mappings into [`LayoutPlan`]s, compiles the pair into a
/// [`CopyProgram`], executes it, and returns the strategy it used.
///
/// One-shot convenience — for repeated copies between the same layout
/// pair, compile the program once with [`CopyProgram::compile`] and
/// execute it per call.
///
/// Panics if the views do not share a data space.
pub fn copy<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>) -> CopyMethod
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    assert!(
        same_data_space(src.mapping(), dst.mapping()),
        "copy between different data spaces: {} vs {}",
        src.mapping().mapping_name(),
        dst.mapping().mapping_name()
    );
    // Compile each side exactly once; the program embeds both plans'
    // knowledge as explicit ops.
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    let prog =
        program::compile_with(src.mapping(), dst.mapping(), &sp, &dp, ChunkOrder::ReadContiguous);
    prog.execute(src, dst);
    prog.method()
}

/// Multi-threaded [`copy`]: compiles one sub-program per plan-aligned
/// shard ([`crate::view::shard::pair_align`] boundaries — runs start
/// lane-blocked on *both* layouts) and executes them on scoped worker
/// threads. Gather-fallback pairs and aliasing destinations (`One`)
/// run serially; so do small inputs, where spawn overhead dominates.
///
/// Panics if the views do not share a data space.
pub fn copy_parallel<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    threads: Option<usize>,
) -> CopyMethod
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob + Sync,
    BD: BlobMut,
{
    assert!(
        same_data_space(src.mapping(), dst.mapping()),
        "copy between different data spaces: {} vs {}",
        src.mapping().mapping_name(),
        dst.mapping().mapping_name()
    );
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    program::run_parallel_with(src, dst, &sp, &dp, ChunkOrder::ReadContiguous, threads)
}

/// Field-wise equality of two views over the same data space (test
/// helper and verification step for the benchmarks).
pub fn views_equal<MS, MD, BS, BD>(a: &View<MS, BS>, b: &View<MD, BD>) -> bool
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: Blob,
{
    if !same_data_space(a.mapping(), b.mapping()) {
        return false;
    }
    let info = a.mapping().info().clone();
    for lin in 0..a.count() {
        for leaf in 0..info.leaf_count() {
            let (anr, aoff) = a
                .mapping()
                .blob_nr_and_offset(leaf, a.mapping().slot_of_lin(lin));
            let (bnr, boff) = b
                .mapping()
                .blob_nr_and_offset(leaf, b.mapping().slot_of_lin(lin));
            let size = info.fields[leaf].size();
            let mut av = a.blobs()[anr].as_bytes()[aoff..aoff + size].to_vec();
            let mut bv = b.blobs()[bnr].as_bytes()[boff..boff + size].to_vec();
            if !a.mapping().is_native_representation() {
                av.reverse();
            }
            if !b.mapping().is_native_representation() {
                bv.reverse();
            }
            if av != bv {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Fill every field of a view with a value derived from (leaf, lin)
    /// so cross-talk is detectable.
    pub fn fill_distinct<M: Mapping, B: BlobMut>(v: &mut View<M, B>) {
        use crate::record::Scalar;
        let info = v.mapping().info().clone();
        for lin in 0..v.count() {
            for leaf in 0..info.leaf_count() {
                let seed = (leaf * 131 + lin * 7 + 3) % 251;
                match info.fields[leaf].scalar {
                    Scalar::F32 => v.set::<f32>(lin, leaf, seed as f32 * 0.5),
                    Scalar::F64 => v.set::<f64>(lin, leaf, seed as f64 * 0.25),
                    Scalar::I8 => v.set::<i8>(lin, leaf, seed as i8),
                    Scalar::I16 => v.set::<i16>(lin, leaf, seed as i16),
                    Scalar::I32 => v.set::<i32>(lin, leaf, seed as i32),
                    Scalar::I64 => v.set::<i64>(lin, leaf, seed as i64),
                    Scalar::U8 => v.set::<u8>(lin, leaf, seed as u8),
                    Scalar::U16 => v.set::<u16>(lin, leaf, seed as u16),
                    Scalar::U32 => v.set::<u32>(lin, leaf, seed as u32),
                    Scalar::U64 => v.set::<u64>(lin, leaf, seed as u64),
                    Scalar::Bool => v.set::<bool>(lin, leaf, seed % 2 == 0),
                }
            }
        }
    }

    /// Assert a freshly-allocated destination receives exactly the
    /// source contents under `copy_fn`.
    pub fn check_copy<MS, MD>(
        src_mapping: MS,
        dst_mapping: MD,
        copy_fn: impl FnOnce(&View<MS, Vec<u8>>, &mut View<MD, Vec<u8>>),
    ) where
        MS: Mapping,
        MD: Mapping,
    {
        let mut src = crate::view::alloc_view(src_mapping);
        let mut dst = crate::view::alloc_view(dst_mapping);
        fill_distinct(&mut src);
        copy_fn(&src, &mut dst);
        assert!(
            views_equal(&src, &dst),
            "copy mismatch {} -> {}",
            src.mapping().mapping_name(),
            dst.mapping().mapping_name()
        );
    }

    #[allow(dead_code)]
    pub fn read_f32<M: Mapping, B: Blob>(v: &View<M, B>, lin: usize, leaf: usize) -> f32 {
        v.get::<f32>(lin, leaf)
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};
    use crate::view::alloc_view;

    #[test]
    fn dispatcher_picks_blobwise_for_identical() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::Blobwise);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn dispatcher_picks_chunked_for_aosoa_family() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(AoSoA::new(&d, ArrayDims::linear(16), 4));
        assert_eq!(copy(&src, &mut dst), CopyMethod::AoSoAChunked);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn dispatcher_compiles_strided_program_for_affine_pairs() {
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
            fill_distinct(&mut v);
            v
        };
        // Aligned AoS is not in the chunkable family, but both sides
        // are affine: strided-run program (field-wise before PR 3).
        let mut dst = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(16)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::Program);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn copy_parallel_matches_serial_across_strategies() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let mut serial = alloc_view(AoSoA::new(&d, dims.clone(), 32));
        assert_eq!(copy(&src, &mut serial), CopyMethod::AoSoAChunked);
        for threads in [1usize, 2, 7] {
            let mut par = alloc_view(AoSoA::new(&d, dims.clone(), 32));
            assert_eq!(copy_parallel(&src, &mut par, Some(threads)), CopyMethod::AoSoAChunked);
            assert_eq!(par.blobs(), serial.blobs(), "threads {threads}");
        }
        // Aliasing destination collapses to one shard and stays safe:
        // like the naive copy, the last record's values win.
        let mut one = alloc_view(crate::mapping::One::new(&d, dims.clone()));
        assert_eq!(copy_parallel(&src, &mut one, Some(8)), CopyMethod::Program);
        let last = src.count() - 1;
        assert_eq!(one.get::<f64>(0, 4), src.get::<f64>(last, 4));
    }

    #[test]
    fn byteswap_affine_pairs_compile_swap_programs() {
        // Exactly one byteswapped side + both affine: per-leaf swap
        // runs, not the element gather (field-wise before the wire PR).
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(Byteswap::new(SoA::multi_blob(&d, ArrayDims::linear(8))));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(8)));
        assert_eq!(copy(&src, &mut dst), CopyMethod::SwapProgram);
        assert!(views_equal(&src, &dst));
        // And the other direction: native → byteswapped packing.
        let mut back = alloc_view(Byteswap::new(AoS::packed(&d, ArrayDims::linear(8))));
        assert_eq!(copy(&dst, &mut back), CopyMethod::SwapProgram);
        assert!(views_equal(&dst, &back));
    }

    #[test]
    fn identical_byteswapped_pairs_move_bytes_verbatim() {
        // Byteswapped pairs of identical inner layout are byte-identical
        // layouts: one memcpy per blob, no per-element swapping.
        let d = particle_dim();
        let src = {
            let mut v = alloc_view(Byteswap::new(SoA::multi_blob(&d, ArrayDims::linear(16))));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(Byteswap::new(SoA::multi_blob(&d, ArrayDims::linear(16))));
        assert_eq!(copy(&src, &mut dst), CopyMethod::Blobwise);
        assert!(views_equal(&src, &dst));
        // Different chunkable layouts, both byteswapped: the chunked
        // strategy moves the swapped bytes verbatim too.
        let mut chunked = alloc_view(Byteswap::new(AoSoA::new(&d, ArrayDims::linear(16), 4)));
        assert_eq!(copy(&src, &mut chunked), CopyMethod::AoSoAChunked);
        assert!(views_equal(&src, &chunked));
    }

    #[test]
    fn byteswap_generic_pairs_stay_fieldwise() {
        // A byteswapped side whose inner addressing is generic (Morton
        // curve) has no closed form: the element gather still applies
        // and converts the representation per field.
        use crate::array::MortonCurve;
        let d = particle_dim();
        let dims = ArrayDims::from([4, 4]);
        let src = {
            let mut v = alloc_view(Byteswap::new(AoS::with_linearizer(
                &d,
                dims.clone(),
                MortonCurve,
                true,
            )));
            fill_distinct(&mut v);
            v
        };
        let mut dst = alloc_view(SoA::multi_blob(&d, dims));
        assert_eq!(copy(&src, &mut dst), CopyMethod::FieldWise);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    #[should_panic(expected = "different data spaces")]
    fn mismatched_extents_panic() {
        let d = particle_dim();
        let src = alloc_view(AoS::aligned(&d, ArrayDims::linear(8)));
        let mut dst = alloc_view(AoS::aligned(&d, ArrayDims::linear(9)));
        let _ = copy(&src, &mut dst);
    }
}
