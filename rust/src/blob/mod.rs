//! **Blobs** (paper §3.8): a blob is any object representing a
//! contiguous chunk of memory. Views interpret blob bytes through their
//! mapping; allocation is fully decoupled via [`BlobAllocator`] so LLAMA
//! stays orthogonal to allocators (paper: owning containers, `std::span`,
//! raw pointers, mapped files, device memory, ...).

pub mod alloc;
pub mod external;
pub mod pool;

pub use alloc::{AlignedAlloc, AlignedBytes, BlobAllocator, VecAlloc};
pub use external::{ExternalBytes, ExternalBytesMut};
pub use pool::{BlobPool, BlobRecycler, PoolStats, PooledBytes};

/// Read access to a contiguous region of memory.
pub trait Blob {
    fn as_bytes(&self) -> &[u8];

    fn len(&self) -> usize {
        self.as_bytes().len()
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Write access to a contiguous region of memory.
pub trait BlobMut: Blob {
    fn as_bytes_mut(&mut self) -> &mut [u8];
}

impl Blob for Vec<u8> {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        self
    }
}

impl BlobMut for Vec<u8> {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        self
    }
}

impl Blob for Box<[u8]> {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        self
    }
}

impl BlobMut for Box<[u8]> {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        self
    }
}

/// Shared immutable blob ownership: a published serving generation
/// hands the *same* blob bytes to every pinned reader by cloning the
/// `Arc`, never the bytes ([`crate::view::serve::ReadGuard`]). Write
/// access deliberately has no impl — a generation is frozen at
/// publish.
impl<B: Blob> Blob for std::sync::Arc<B> {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        (**self).as_bytes()
    }
}

impl<const N: usize> Blob for [u8; N] {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        self
    }
}

impl<const N: usize> BlobMut for [u8; N] {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_blob() {
        let mut v = vec![0u8; 8];
        assert_eq!(v.as_bytes().len(), 8);
        v.as_bytes_mut()[3] = 7;
        assert_eq!(v[3], 7);
        assert!(!Blob::is_empty(&v));
    }

    #[test]
    fn fixed_array_blob() {
        let mut a = [0u8; 16];
        a.as_bytes_mut()[0] = 1;
        assert_eq!(Blob::len(&a), 16);
        assert_eq!(a.as_bytes()[0], 1);
    }
}
