//! [`BlobPool`]: an llmalloc-style recycling blob pool — layer 0 of the
//! plan → shard → program → adapt stack (ARCHITECTURE.md "layer 0 —
//! memory", EXPERIMENTS.md §Alloc).
//!
//! The paper's §3.8 makes allocation an exchangeable policy
//! (`allocView(mapping, blobAlloc)`); this module supplies the policy
//! that makes *churning* allocation patterns cheap: adaptive-engine
//! migrations, double-buffer flips and frame-arena turnover allocate
//! the same few blob shapes over and over, so instead of round-tripping
//! through the system allocator (and re-faulting fresh zero pages),
//! returned blobs park on per-size-class free lists and the next
//! request of the same class pops one back out.
//!
//! The design follows llmalloc's size-class scheme:
//!
//! * **Power-of-two size classes** — a request of `size` bytes is
//!   served from the class `next_power_of_two(max(size, 64))`; the blob
//!   exposes exactly `size` bytes, the class capacity stays with the
//!   block so a recycled block can serve any request of its class.
//!   Requests beyond the largest power-of-two class
//!   ([`MAX_CLASS_BYTES`]) are refused with a panic — a non-power-of-
//!   two "class" would break the free-list keying invariant (and no
//!   such allocation can succeed anyway).
//! * **Alignment tiers** — small classes are cache-line aligned (64 B),
//!   classes from one page up are page-aligned (4 KiB), and classes
//!   from 2 MiB up get large-page alignment (llmalloc's
//!   `LARGE_PAGE_SIZE`), so pooled SoA subarrays vectorize and huge
//!   lattice blobs are THP-friendly.
//! * **Zero-on-reuse rule** — [`BlobAllocator::allocate`] always
//!   returns zeroed bytes (fresh blocks come from `alloc_zeroed`,
//!   recycled blocks are re-zeroed over the exposed range).
//!   [`BlobRecycler::allocate_covered`] skips the re-zero; callers may
//!   use it **only** with proof that every exposed byte will be
//!   overwritten — the adaptive engine derives that proof from the
//!   compiled [`crate::copy::CopyProgram`]'s destination spans
//!   ([`crate::copy::programs_cover_dst`]).
//!
//! Blobs return to the pool automatically: [`PooledBytes`] holds a weak
//! handle and its `Drop` pushes the block back on the owning class's
//! free list (or frees it if the pool is gone). [`PoolStats`] counts
//! hits/misses/outstanding/recycled bytes so tests and benches can
//! assert a warm engine performs zero fresh allocations.

use std::sync::{Arc, Mutex, Weak};

use super::alloc::AlignedBytes;
use super::{Blob, BlobAllocator, BlobMut};

/// Smallest size class (one cache line) — every pooled block is at
/// least cache-line sized and cache-line aligned.
pub const MIN_CLASS_BYTES: usize = 64;

/// Largest size class: the biggest power of two representable in
/// `usize` (2^63 on 64-bit). Requests above this have no power-of-two
/// class and are refused by [`class_of`].
pub const MAX_CLASS_BYTES: usize = 1 << (usize::BITS - 1);

/// Classes at or above one page are page-aligned.
pub const PAGE_BYTES: usize = 4096;

/// Classes at or above llmalloc's large-page size get 2 MiB alignment
/// (transparent-huge-page friendly).
pub const LARGE_PAGE_BYTES: usize = 2 * 1024 * 1024;

/// The size class serving a request: the next power of two at or above
/// `max(size, MIN_CLASS_BYTES)`.
///
/// # Panics
/// If `size` exceeds [`MAX_CLASS_BYTES`]: there is no power-of-two
/// class for it, and silently handing back a non-power-of-two "class"
/// (the old fallback) would desync the free-list keys — a returned
/// block is parked under its full block length, which recycled
/// requests then never match.
pub fn class_of(size: usize) -> usize {
    size.max(MIN_CLASS_BYTES).checked_next_power_of_two().unwrap_or_else(|| {
        panic!(
            "blob::pool: request of {size} bytes exceeds the largest \
             size class ({MAX_CLASS_BYTES} bytes)"
        )
    })
}

/// The alignment tier of a size class: cache line, page, or large page.
pub fn class_align(class: usize) -> usize {
    if class >= LARGE_PAGE_BYTES {
        LARGE_PAGE_BYTES
    } else if class >= PAGE_BYTES {
        PAGE_BYTES
    } else {
        MIN_CLASS_BYTES
    }
}

/// Counters of one [`BlobPool`] (all monotonic except `outstanding`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Requests served from a free list (no system allocation).
    pub hits: usize,
    /// Requests that had to allocate a fresh block.
    pub misses: usize,
    /// Blobs currently handed out and not yet returned.
    pub outstanding: usize,
    /// Total requested bytes served from free lists.
    pub recycled_bytes: usize,
    /// Recycled serves that skipped the re-zero because the caller
    /// promised a full overwrite: [`BlobRecycler::allocate_covered`]
    /// calls (coverage-proven migrations) and [`PooledBytes::clone`]
    /// (which copies over every exposed byte).
    pub zero_skips: usize,
}

#[derive(Debug, Default)]
struct PoolInner {
    /// Free blocks, keyed by class size (each block's full length).
    classes: std::collections::BTreeMap<usize, Vec<AlignedBytes>>,
    stats: PoolStats,
}

fn lock(inner: &Mutex<PoolInner>) -> std::sync::MutexGuard<'_, PoolInner> {
    inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A recycling blob allocator (see the [module docs](self)). The
/// handle is a cheap `Arc` clone — every clone shares the same free
/// lists, so a pool can be threaded through views, engines and stores.
///
/// ```
/// use llama::prelude::*;
///
/// let d = llama::record_dim! { x: f32, y: f32 };
/// let pool = BlobPool::new();
/// {
///     let v = alloc_view_with(SoA::multi_blob(&d, ArrayDims::linear(1024)), pool.clone());
///     assert_eq!(v.blobs().len(), 2);
///     assert_eq!(pool.stats().misses, 2); // cold pool: fresh blocks
/// } // dropping the view returns both blobs to their size class
/// let v = alloc_view_with(SoA::multi_blob(&d, ArrayDims::linear(1024)), pool.clone());
/// assert_eq!(pool.stats().hits, 2); // warm pool: zero fresh allocations
/// assert!(v.blobs().iter().all(|b| b.as_bytes().iter().all(|&x| x == 0)));
/// ```
#[derive(Debug, Clone, Default)]
pub struct BlobPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl BlobPool {
    /// An empty pool (no free blocks, zeroed stats).
    pub fn new() -> BlobPool {
        BlobPool::default()
    }

    /// A snapshot of the pool's counters.
    pub fn stats(&self) -> PoolStats {
        lock(&self.inner).stats
    }

    /// Number of blocks currently parked on free lists.
    pub fn free_blocks(&self) -> usize {
        lock(&self.inner).classes.values().map(|v| v.len()).sum()
    }

    /// Drop every parked free block (returns their bytes to the system
    /// allocator). Outstanding blobs are unaffected and still return
    /// to the pool when dropped.
    pub fn trim(&self) {
        lock(&self.inner).classes.clear();
    }

    fn acquire(&self, size: usize, zero: bool) -> PooledBytes {
        if size == 0 {
            // Zero-size blobs carry no storage and never pool.
            return PooledBytes { block: None, len: 0, pool: Weak::new() };
        }
        let class = class_of(size);
        let mut inner = lock(&self.inner);
        let block = match inner.classes.get_mut(&class).and_then(|v| v.pop()) {
            Some(mut b) => {
                inner.stats.hits += 1;
                inner.stats.recycled_bytes += size;
                if zero {
                    b.as_bytes_mut()[..size].fill(0);
                } else {
                    inner.stats.zero_skips += 1;
                }
                b
            }
            None => {
                inner.stats.misses += 1;
                // Fresh blocks come from alloc_zeroed at the class's
                // alignment tier.
                AlignedBytes::new(class, class_align(class))
            }
        };
        inner.stats.outstanding += 1;
        drop(inner);
        PooledBytes { block: Some(block), len: size, pool: Arc::downgrade(&self.inner) }
    }
}

impl BlobAllocator for BlobPool {
    type Blob = PooledBytes;

    fn allocate(&self, size: usize) -> PooledBytes {
        self.acquire(size, true)
    }
}

/// A blob drawn from a [`BlobPool`]: exposes exactly the requested
/// `len` bytes of a class-sized, tier-aligned block, and returns the
/// block to its size class on drop (or frees it if the pool is gone).
#[derive(Debug)]
pub struct PooledBytes {
    /// `None` only for zero-size blobs and mid-drop.
    block: Option<AlignedBytes>,
    len: usize,
    pool: Weak<Mutex<PoolInner>>,
}

impl PooledBytes {
    /// Full capacity of the backing block (the size class), of which
    /// only `len()` bytes are exposed.
    pub fn capacity(&self) -> usize {
        self.block.as_ref().map_or(0, |b| b.as_bytes().len())
    }

    /// Start alignment of the backing block (the class's tier).
    pub fn align(&self) -> usize {
        self.block.as_ref().map_or(MIN_CLASS_BYTES, |b| b.align())
    }
}

impl Blob for PooledBytes {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        match &self.block {
            Some(b) => &b.as_bytes()[..self.len],
            None => &[],
        }
    }
}

impl BlobMut for PooledBytes {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        match &mut self.block {
            Some(b) => &mut b.as_bytes_mut()[..self.len],
            None => &mut [],
        }
    }
}

impl Drop for PooledBytes {
    fn drop(&mut self) {
        let Some(block) = self.block.take() else {
            return;
        };
        match self.pool.upgrade() {
            Some(inner) => {
                let mut inner = lock(&inner);
                inner.stats.outstanding -= 1;
                inner.classes.entry(block.as_bytes().len()).or_default().push(block);
            }
            // Pool gone: the block frees like any AlignedBytes.
            None => drop(block),
        }
    }
}

/// Cloning draws a fresh blob (from the pool when it is still alive)
/// and copies the exposed bytes — pool semantics are preserved, so
/// `View::clone` works over pooled storage.
impl Clone for PooledBytes {
    fn clone(&self) -> Self {
        let mut out = match self.pool.upgrade() {
            // Full overwrite below: the re-zero may be skipped.
            Some(inner) => BlobPool { inner }.acquire(self.len, false),
            None => {
                let class = class_of(self.len);
                PooledBytes {
                    block: (self.len > 0).then(|| AlignedBytes::new(class, class_align(class))),
                    len: self.len,
                    pool: Weak::new(),
                }
            }
        };
        out.as_bytes_mut().copy_from_slice(self.as_bytes());
        out
    }
}

/// Equality over the *exposed* bytes (capacity and pool identity are
/// allocation details) — lets differential tests compare pooled blobs
/// against `Vec<u8>` oracles blob-for-blob.
impl PartialEq for PooledBytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_bytes() == other.as_bytes()
    }
}

impl Eq for PooledBytes {}

impl PartialEq<Vec<u8>> for PooledBytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_bytes() == other.as_slice()
    }
}

/// A [`BlobAllocator`] that can additionally hand out blobs whose
/// contents the caller promises to overwrite completely, skipping the
/// zero fill. Every allocator is trivially a recycler (the default
/// method just zeroes); only pooling allocators gain from the skip.
///
/// The contract of [`BlobRecycler::allocate_covered`]: the caller must
/// overwrite **every** exposed byte before any read — the adaptive
/// engine proves this per migration from the compiled copy program's
/// destination spans ([`crate::copy::programs_cover_dst`]) and falls
/// back to the zeroed [`BlobAllocator::allocate`] otherwise. The
/// method is safe either way (recycled bytes are this process's own
/// prior blob contents, never foreign memory); the rule exists so
/// blob bytes stay bit-identical to a fresh-zeroed run.
pub trait BlobRecycler: BlobAllocator {
    /// Allocate `size` bytes that the caller will fully overwrite;
    /// implementations may skip the zero fill on recycled memory.
    fn allocate_covered(&self, size: usize) -> Self::Blob {
        self.allocate(size)
    }

    /// The recycler's counters, if it keeps any.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }
}

impl BlobRecycler for super::alloc::VecAlloc {}

impl BlobRecycler for super::alloc::AlignedAlloc {}

impl<R: BlobRecycler> BlobRecycler for &R {
    fn allocate_covered(&self, size: usize) -> Self::Blob {
        // UFCS to avoid autoref recursion into this impl.
        R::allocate_covered(self, size)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        R::pool_stats(self)
    }
}

impl<R: BlobRecycler> BlobRecycler for Arc<R> {
    fn allocate_covered(&self, size: usize) -> Self::Blob {
        R::allocate_covered(self, size)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        R::pool_stats(self)
    }
}

impl BlobRecycler for BlobPool {
    fn allocate_covered(&self, size: usize) -> PooledBytes {
        self.acquire(size, false)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding_and_alignment_tiers() {
        assert_eq!(class_of(0), 64);
        assert_eq!(class_of(1), 64);
        assert_eq!(class_of(64), 64);
        assert_eq!(class_of(65), 128);
        assert_eq!(class_of(4096), 4096);
        assert_eq!(class_of(4097), 8192);
        // Boundary: the largest class is served exactly...
        assert_eq!(class_of(MAX_CLASS_BYTES), MAX_CLASS_BYTES);
        assert_eq!(class_of(MAX_CLASS_BYTES - 1), MAX_CLASS_BYTES);
        assert_eq!(class_align(64), 64);
        assert_eq!(class_align(2048), 64);
        assert_eq!(class_align(4096), 4096);
        assert_eq!(class_align(1 << 20), 4096);
        assert_eq!(class_align(LARGE_PAGE_BYTES), LARGE_PAGE_BYTES);
        assert_eq!(class_align(LARGE_PAGE_BYTES * 4), LARGE_PAGE_BYTES);
    }

    #[test]
    #[should_panic(expected = "exceeds the largest size class")]
    fn oversized_requests_are_refused_not_misclassed() {
        // ...and one byte past it is refused. The old fallback returned
        // `size` itself here — a non-power-of-two class whose free-list
        // key no later request could reproduce.
        class_of(MAX_CLASS_BYTES + 1);
    }

    #[test]
    fn allocate_exposes_exact_len_over_class_capacity() {
        let pool = BlobPool::new();
        let b = pool.allocate(100);
        assert_eq!(b.as_bytes().len(), 100);
        assert_eq!(b.capacity(), 128);
        assert_eq!(b.as_bytes().as_ptr() as usize % 64, 0);
        assert!(b.as_bytes().iter().all(|&x| x == 0));
        assert_eq!(pool.stats().outstanding, 1);
        drop(b);
        assert_eq!(pool.stats().outstanding, 0);
        assert_eq!(pool.free_blocks(), 1);
    }

    #[test]
    fn recycle_hands_the_block_back_and_zeroes() {
        let pool = BlobPool::new();
        let mut a = pool.allocate(200);
        a.as_bytes_mut().fill(0xAB);
        let addr = a.as_bytes().as_ptr() as usize;
        drop(a);
        // Same class (256): the block comes back, re-zeroed.
        let b = pool.allocate(256);
        assert_eq!(b.as_bytes().as_ptr() as usize, addr);
        assert!(b.as_bytes().iter().all(|&x| x == 0), "reuse must re-zero");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.recycled_bytes, 256);
        assert_eq!(s.zero_skips, 0);
    }

    #[test]
    fn allocate_covered_skips_the_zero() {
        let pool = BlobPool::new();
        let mut a = pool.allocate(64);
        a.as_bytes_mut().fill(0xCD);
        drop(a);
        let b = pool.allocate_covered(64);
        // Contract: contents are arbitrary (here: the old fill).
        assert_eq!(b.as_bytes()[0], 0xCD);
        assert_eq!(pool.stats().zero_skips, 1);
        // A fresh (miss) covered allocation is still zeroed memory.
        let c = pool.allocate_covered(1 << 14);
        assert!(c.as_bytes().iter().all(|&x| x == 0));
    }

    #[test]
    fn distinct_outstanding_blobs_never_alias() {
        let pool = BlobPool::new();
        let mut blobs: Vec<PooledBytes> = (0..8).map(|_| pool.allocate(96)).collect();
        for (i, b) in blobs.iter_mut().enumerate() {
            b.as_bytes_mut().fill(i as u8 + 1);
        }
        for (i, b) in blobs.iter().enumerate() {
            assert!(b.as_bytes().iter().all(|&x| x == i as u8 + 1), "blob {i} clobbered");
        }
        assert_eq!(pool.stats().outstanding, 8);
    }

    #[test]
    fn zero_size_blobs_skip_the_pool() {
        let pool = BlobPool::new();
        let b = pool.allocate(0);
        assert!(b.as_bytes().is_empty());
        assert_eq!(b.capacity(), 0);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.outstanding), (0, 0, 0));
    }

    #[test]
    fn clone_copies_bytes_through_the_pool() {
        let pool = BlobPool::new();
        let mut a = pool.allocate(70);
        a.as_bytes_mut()[69] = 9;
        let b = a.clone();
        assert_eq!(a, b);
        assert_ne!(a.as_bytes().as_ptr(), b.as_bytes().as_ptr());
        assert_eq!(pool.stats().outstanding, 2);
    }

    #[test]
    fn outstanding_blobs_survive_the_pool() {
        let pool = BlobPool::new();
        let mut b = pool.allocate(128);
        drop(pool);
        b.as_bytes_mut()[0] = 1; // still a valid blob
        assert_eq!(b.as_bytes()[0], 1);
        drop(b); // weak upgrade fails: the block frees directly
    }

    #[test]
    fn trim_drops_free_blocks_only() {
        let pool = BlobPool::new();
        let keep = pool.allocate(64);
        drop(pool.allocate(64));
        assert_eq!(pool.free_blocks(), 1);
        pool.trim();
        assert_eq!(pool.free_blocks(), 0);
        assert_eq!(keep.as_bytes().len(), 64);
        drop(keep);
        assert_eq!(pool.free_blocks(), 1);
    }

    /// Compile-time thread-safety contracts: the pool handle crosses
    /// threads freely (shared free lists behind a mutex), and pooled
    /// blobs — including the `Arc`'d form a published serving
    /// generation shares with its readers — move and share too.
    #[test]
    fn pool_types_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<BlobPool>();
        assert_send_sync::<PooledBytes>();
        assert_send_sync::<AlignedBytes>();
        assert_send_sync::<Arc<PooledBytes>>();
        assert_send_sync::<Arc<BlobPool>>();
        assert_send_sync::<Vec<Arc<PooledBytes>>>();
    }

    /// The `Arc<R>` recycler delegates to the shared pool, stats
    /// included.
    #[test]
    fn arc_recycler_delegates_to_the_shared_pool() {
        let pool = Arc::new(BlobPool::new());
        drop(pool.allocate(64));
        let b = pool.allocate_covered(64);
        assert_eq!(pool.pool_stats().unwrap().zero_skips, 1);
        assert_eq!(b.as_bytes().len(), 64);
    }

    #[test]
    fn vec_alloc_is_a_trivial_recycler() {
        use crate::blob::VecAlloc;
        let b = VecAlloc.allocate_covered(32);
        assert!(b.iter().all(|&x| x == 0));
        assert!(VecAlloc.pool_stats().is_none());
    }
}
