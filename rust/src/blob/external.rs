//! Non-owning blobs over external memory (paper §3.8: views can operate
//! on "non-owning constructs like `std::span<std::byte>`, raw pointers,
//! memory mapped files, ..."). This is what lets a LLAMA view
//! reinterpret e.g. a buffer prepared by a third-party API — the
//! PIConGPU integration (paper §4.4) relies on exactly this.

use super::{Blob, BlobMut};

/// Read-only borrow of external bytes.
#[derive(Debug, Clone, Copy)]
pub struct ExternalBytes<'a>(pub &'a [u8]);

impl Blob for ExternalBytes<'_> {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        self.0
    }
}

/// Mutable borrow of external bytes.
#[derive(Debug)]
pub struct ExternalBytesMut<'a>(pub &'a mut [u8]);

impl Blob for ExternalBytesMut<'_> {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        self.0
    }
}

impl BlobMut for ExternalBytesMut<'_> {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn external_view_over_stack_buffer() {
        let mut storage = [0u8; 16];
        {
            let mut b = ExternalBytesMut(&mut storage);
            b.as_bytes_mut()[5] = 42;
            assert_eq!(b.as_bytes()[5], 42);
        }
        assert_eq!(storage[5], 42);
        let ro = ExternalBytes(&storage);
        assert_eq!(ro.as_bytes()[5], 42);
        assert_eq!(Blob::len(&ro), 16);
    }
}
