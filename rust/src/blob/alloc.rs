//! Blob allocators (paper §3.8: `allocView(mapping, blobAlloc)`).

use super::{Blob, BlobMut};

/// A blob allocator: callable producing one blob of a requested size.
/// Passed to [`crate::view::alloc_view_with`].
pub trait BlobAllocator {
    type Blob: BlobMut;

    fn allocate(&self, size: usize) -> Self::Blob;
}

/// Allocators work by reference too, so a holder (a frame store, the
/// adaptive engine) can keep one allocator and allocate many blobs.
impl<A: BlobAllocator> BlobAllocator for &A {
    type Blob = A::Blob;

    fn allocate(&self, size: usize) -> A::Blob {
        // UFCS: plain method syntax on `*self: &A` would autoref back
        // into this impl and recurse.
        A::allocate(self, size)
    }
}

/// And behind shared ownership: a serving fleet hands one allocator
/// (typically a [`crate::blob::BlobPool`]) to many stores as an `Arc`.
impl<A: BlobAllocator> BlobAllocator for std::sync::Arc<A> {
    type Blob = A::Blob;

    fn allocate(&self, size: usize) -> A::Blob {
        A::allocate(self, size)
    }
}

/// Default allocator: zero-initialized `Vec<u8>`.
#[derive(Debug, Clone, Copy, Default)]
pub struct VecAlloc;

impl BlobAllocator for VecAlloc {
    type Blob = Vec<u8>;

    fn allocate(&self, size: usize) -> Vec<u8> {
        vec![0u8; size]
    }
}

/// Bytes with a guaranteed start alignment (e.g. 64 for cache lines or
/// 4096 for pages) — the paper's aligned allocator use case for
/// vectorized loads on SoA subarrays.
#[derive(Debug)]
pub struct AlignedBytes {
    ptr: *mut u8,
    size: usize,
    align: usize,
}

// SAFETY: AlignedBytes uniquely owns its allocation, like Vec<u8>.
unsafe impl Send for AlignedBytes {}
unsafe impl Sync for AlignedBytes {}

impl AlignedBytes {
    pub fn new(size: usize, align: usize) -> Self {
        assert!(align.is_power_of_two());
        if size == 0 {
            return AlignedBytes { ptr: std::ptr::null_mut(), size: 0, align };
        }
        let layout = std::alloc::Layout::from_size_align(size, align).expect("bad layout");
        // SAFETY: size > 0, layout valid.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "allocation of {size} bytes failed");
        AlignedBytes { ptr, size, align }
    }

    pub fn align(&self) -> usize {
        self.align
    }
}

/// Cloning allocates fresh at the same alignment and copies the bytes
/// — so `View<M, AlignedBytes>` works everywhere a cloneable-view API
/// expects `Vec<u8>` blobs.
impl Clone for AlignedBytes {
    fn clone(&self) -> Self {
        let mut out = AlignedBytes::new(self.size, self.align);
        out.as_bytes_mut().copy_from_slice(self.as_bytes());
        out
    }
}

impl Drop for AlignedBytes {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            let layout =
                std::alloc::Layout::from_size_align(self.size, self.align).expect("bad layout");
            // SAFETY: allocated with the same layout in new().
            unsafe { std::alloc::dealloc(self.ptr, layout) };
        }
    }
}

impl Blob for AlignedBytes {
    #[inline]
    fn as_bytes(&self) -> &[u8] {
        if self.ptr.is_null() {
            &[]
        } else {
            // SAFETY: ptr valid for size bytes, owned by self.
            unsafe { std::slice::from_raw_parts(self.ptr, self.size) }
        }
    }
}

impl BlobMut for AlignedBytes {
    #[inline]
    fn as_bytes_mut(&mut self) -> &mut [u8] {
        if self.ptr.is_null() {
            &mut []
        } else {
            // SAFETY: ptr valid for size bytes, exclusively borrowed.
            unsafe { std::slice::from_raw_parts_mut(self.ptr, self.size) }
        }
    }
}

/// Allocator producing [`AlignedBytes`] with a fixed alignment.
#[derive(Debug, Clone, Copy)]
pub struct AlignedAlloc {
    pub align: usize,
}

impl AlignedAlloc {
    /// Cache-line alignment (64 B), the common HPC default.
    pub fn cache_line() -> Self {
        AlignedAlloc { align: 64 }
    }

    /// Page alignment (4 KiB).
    pub fn page() -> Self {
        AlignedAlloc { align: 4096 }
    }
}

impl BlobAllocator for AlignedAlloc {
    type Blob = AlignedBytes;

    fn allocate(&self, size: usize) -> AlignedBytes {
        AlignedBytes::new(size, self.align)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_alloc_zeroed() {
        let b = VecAlloc.allocate(32);
        assert_eq!(b.len(), 32);
        assert!(b.iter().all(|&x| x == 0));
    }

    #[test]
    fn aligned_alloc_alignment() {
        for align in [16, 64, 4096] {
            let b = AlignedAlloc { align }.allocate(100);
            assert_eq!(b.as_bytes().as_ptr() as usize % align, 0);
            assert_eq!(b.as_bytes().len(), 100);
            assert!(b.as_bytes().iter().all(|&x| x == 0));
        }
    }

    #[test]
    fn aligned_alloc_write_read() {
        let mut b = AlignedAlloc::cache_line().allocate(64);
        b.as_bytes_mut()[63] = 0xAB;
        assert_eq!(b.as_bytes()[63], 0xAB);
    }

    #[test]
    fn clone_preserves_bytes_and_alignment() {
        let mut a = AlignedAlloc::page().allocate(100);
        a.as_bytes_mut()[63] = 0xEE;
        let b = a.clone();
        assert_eq!(b.as_bytes(), a.as_bytes());
        assert_eq!(b.align(), 4096);
        assert_eq!(b.as_bytes().as_ptr() as usize % 4096, 0);
        assert_ne!(b.as_bytes().as_ptr(), a.as_bytes().as_ptr());
        // Zero-size clones stay empty and harmless.
        let z = AlignedBytes::new(0, 64).clone();
        assert!(z.as_bytes().is_empty());
    }

    #[test]
    fn by_ref_allocator_delegates() {
        let alloc = AlignedAlloc::cache_line();
        let b = (&alloc).allocate(32);
        assert_eq!(b.as_bytes().len(), 32);
        assert_eq!(b.as_bytes().as_ptr() as usize % 64, 0);
    }

    #[test]
    fn zero_size_blob() {
        let b = AlignedAlloc::page().allocate(0);
        assert!(b.as_bytes().is_empty());
    }
}
