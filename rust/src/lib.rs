//! # LLAMA — the Low-Level Abstraction of Memory Access, in Rust
//!
//! A reproduction of *LLAMA: The Low-Level Abstraction for Memory
//! Access* (Gruber et al., Software: Practice & Experience 2021, DOI
//! 10.1002/spe.3077) as a Rust + JAX + Pallas three-layer stack.
//!
//! Programs are written against an abstract **data space** — runtime
//! [`array::ArrayDims`] × compile-time [`record::RecordDim`] — and the
//! physical memory layout is supplied separately as an exchangeable
//! [`mapping::Mapping`] (AoS, SoA, AoSoA, One, Split, Trace, Heatmap,
//! ...). [`view::View`]s combine a mapping with [`blob::Blob`] storage;
//! [`copy`] moves data between views of different layouts in the largest
//! chunks both layouts admit.
//!
//! ```
//! use llama::prelude::*;
//!
//! let particle = llama::record_dim! {
//!     pos: { x: f32, y: f32, z: f32 },
//!     mass: f32,
//!     vel: { x: f32, y: f32, z: f32 },
//! };
//! let dims = ArrayDims::linear(1024);
//!
//! // Switch the layout by changing one line (paper §4.3):
//! let mapping = SoA::multi_blob(&particle, dims);
//! let mut view = alloc_view(mapping);
//!
//! let mass = view.mapping().info().leaf_by_path("mass").unwrap();
//! for i in 0..view.count() {
//!     view.set::<f32>(i, mass, 1.0);
//! }
//! assert_eq!(view.get::<f32>(1023, mass), 1.0);
//! ```
//!
//! # Module tree — the four-layer stack (see `ARCHITECTURE.md`)
//!
//! * **Data space** — [`record`] (compile-time record dimension) ×
//!   [`array`] (runtime array dimensions).
//! * **Mapping → plan** — [`mapping`]: layout functions, each compiled
//!   into an executable [`mapping::LayoutPlan`] ([`mapping::plan`]);
//!   [`mapping::advisor`] recommends layouts from traced statistics.
//! * **Access & scale** — [`view`]: views over blobs, zero-overhead
//!   cursors ([`view::cursor`]), plan-aligned parallel sharding
//!   ([`view::shard`]), runtime-dispatched SIMD execution
//!   ([`view::simd`], `simd` feature), the adaptive relayout
//!   engine ([`view::adapt`]), and the concurrent serving layer —
//!   epoch-pinned reads during background relayout under a fleet
//!   migration budget ([`view::serve`]).
//! * **Copy** — [`copy`]: layout-changing copies compiled once into
//!   [`copy::CopyProgram`]s ([`copy::program`]), and layout-aware
//!   serialization over process boundaries ([`copy::wire`]: a
//!   self-describing manifest + a compiled pack/unpack, cross-endian
//!   included).
//!
//! Supporting modules: [`blob`] (storage: owned, aligned, external,
//! and the recycling [`blob::pool`] — layer 0), [`dump`] (fig 4 layout
//! visualizations), [`error`] (in-tree error plumbing), [`workloads`]
//! (n-body, D3Q19 LBM, HEP events, PIConGPU-style frames),
//! [`runtime`] (PJRT execution of JAX/Pallas AOT artifacts, `xla`
//! feature), [`coordinator`] (benchmark drivers + CLI).

pub mod array;
pub mod blob;
pub mod coordinator;
#[warn(missing_docs)]
pub mod copy;
pub mod dump;
pub mod error;
#[warn(missing_docs)]
pub mod mapping;
#[macro_use]
pub mod record;
pub mod runtime;
#[warn(missing_docs)]
pub mod view;
pub mod workloads;

/// The paper's listing-1 Particle record (id, pos, mass, flags) — used
/// by the fig 4 layout dumps and the quickstart example.
pub fn mapping_demo_dim() -> record::RecordDim {
    record_dim! {
        id: u16,
        pos: { x: f32, y: f32, z: f32 },
        mass: f64,
        flags: [bool; 3],
    }
}

/// Convenient glob import for examples and applications.
pub mod prelude {
    pub use crate::array::{
        ArrayDims, ArrayIndexRange, ColMajor, HilbertCurve2D, MortonCurve, RowMajor,
    };
    pub use crate::blob::{
        AlignedAlloc, Blob, BlobAllocator, BlobMut, BlobPool, BlobRecycler, PoolStats,
        PooledBytes, VecAlloc,
    };
    pub use crate::copy::{
        aosoa_copy, copy, copy_blobwise, copy_naive, copy_parallel, copy_stdcopy, deserialize,
        deserialize_into, deserialize_range_into, deserialize_range_into_at,
        deserialize_sharded_into, programs_cover_dst, read_message, serialize, serialize_endian,
        serialize_range, serialize_range_endian, serialize_range_with, serialize_sharded,
        serialize_with, views_equal, wire_view, write_message, write_range_chunked, ChunkOrder,
        CopyMethod, CopyOp, CopyProgram, ProgramCache, WireMessage, CHUNK_MAGIC, MAX_HEADER_BYTES,
    };
    pub use crate::dump::{dump_html, dump_svg, heatmap_ascii};
    pub use crate::mapping::{
        estimated_bytes_per_record, migration_gain, recommend, recommend_stats, AccessPattern,
        AddrPlan, AoS, AoSoA, Byteswap, CostModel, DynMapping, FieldStats, Heatmap,
        HeatmapSnapshot, LayoutPlan, Mapping, Null, One, RecipeMapping, Recommendation, SoA,
        Split, Trace, TraceSnapshot, WireRecipe,
    };
    pub use crate::runtime::{WireEndian, WireManifest};
    pub use crate::record::{Field, RecordCoord, RecordDim, RecordInfo, Scalar, Type};
    pub use crate::view::{
        alloc_view, alloc_view_with, migrate_with, pair_align, par_execute, par_execute_zip,
        par_map_shards, par_shards, plan_aliases, shard_align, shard_pair, shard_plan,
        shard_range, simd_compiled, AdaptiveConfig, AdaptiveKernel, AdaptiveKernel2,
        AdaptiveView, AdvisorPool, CursorRead, CursorWrite, CycleEntry, CycleReport, OneRecord,
        PendingMigration, ReadGuard, ScalarVal, ServingEngine, Shard, ShardKernel, ShardKernel2,
        SimdCursorRead, SimdCursorWrite, SimdPath, View,
    };
}
