//! # LLAMA — the Low-Level Abstraction of Memory Access, in Rust
//!
//! A reproduction of *LLAMA: The Low-Level Abstraction for Memory
//! Access* (Gruber et al., Software: Practice & Experience 2021, DOI
//! 10.1002/spe.3077) as a Rust + JAX + Pallas three-layer stack.
//!
//! Programs are written against an abstract **data space** — runtime
//! [`array::ArrayDims`] × compile-time [`record::RecordDim`] — and the
//! physical memory layout is supplied separately as an exchangeable
//! [`mapping::Mapping`] (AoS, SoA, AoSoA, One, Split, Trace, Heatmap,
//! ...). [`view::View`]s combine a mapping with [`blob::Blob`] storage;
//! [`copy`] moves data between views of different layouts in the largest
//! chunks both layouts admit.
//!
//! ```
//! use llama::prelude::*;
//!
//! let particle = llama::record_dim! {
//!     pos: { x: f32, y: f32, z: f32 },
//!     mass: f32,
//!     vel: { x: f32, y: f32, z: f32 },
//! };
//! let dims = ArrayDims::linear(1024);
//!
//! // Switch the layout by changing one line (paper §4.3):
//! let mapping = SoA::multi_blob(&particle, dims);
//! let mut view = alloc_view(mapping);
//!
//! let mass = view.mapping().info().leaf_by_path("mass").unwrap();
//! for i in 0..view.count() {
//!     view.set::<f32>(i, mass, 1.0);
//! }
//! assert_eq!(view.get::<f32>(1023, mass), 1.0);
//! ```
//!
//! The evaluation workloads (n-body, D3Q19 LBM, HEP event records,
//! PIConGPU-style particle frames) live under [`workloads`]; the PJRT
//! runtime executing the JAX/Pallas AOT artifacts lives under
//! [`runtime`]; the benchmark drivers under [`coordinator`].

pub mod array;
pub mod blob;
pub mod coordinator;
pub mod copy;
pub mod dump;
pub mod error;
pub mod mapping;
#[macro_use]
pub mod record;
pub mod runtime;
pub mod view;
pub mod workloads;

/// The paper's listing-1 Particle record (id, pos, mass, flags) — used
/// by the fig 4 layout dumps and the quickstart example.
pub fn mapping_demo_dim() -> record::RecordDim {
    record_dim! {
        id: u16,
        pos: { x: f32, y: f32, z: f32 },
        mass: f64,
        flags: [bool; 3],
    }
}

/// Convenient glob import for examples and applications.
pub mod prelude {
    pub use crate::array::{
        ArrayDims, ArrayIndexRange, ColMajor, HilbertCurve2D, MortonCurve, RowMajor,
    };
    pub use crate::blob::{AlignedAlloc, Blob, BlobAllocator, BlobMut, VecAlloc};
    pub use crate::copy::{
        aosoa_copy, copy, copy_blobwise, copy_naive, copy_parallel, copy_stdcopy, views_equal,
        ChunkOrder, CopyMethod, CopyOp, CopyProgram,
    };
    pub use crate::dump::{dump_html, dump_svg, heatmap_ascii};
    pub use crate::mapping::{
        recommend, AccessPattern, AddrPlan, AoS, AoSoA, Byteswap, Heatmap, LayoutPlan, Mapping,
        Null, One, Recommendation, SoA, Split, Trace,
    };
    pub use crate::record::{Field, RecordCoord, RecordDim, RecordInfo, Scalar, Type};
    pub use crate::view::{
        alloc_view, alloc_view_with, pair_align, par_execute, par_execute_zip, par_map_shards,
        par_shards, plan_aliases, shard_align, shard_pair, shard_plan, shard_range, CursorRead,
        CursorWrite, OneRecord, ScalarVal, Shard, ShardKernel, ShardKernel2, View,
    };
}
