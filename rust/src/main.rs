//! `llama` binary entry point: see `llama --help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match llama::coordinator::cli::parse(&args) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = llama::coordinator::cli::run(cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
