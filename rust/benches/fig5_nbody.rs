//! `cargo bench --bench fig5_nbody` — regenerates paper fig 5:
//! n-body CPU update/move across layouts, manual twins vs LLAMA.
//! Env: LLAMA_BENCH_QUICK=1 for small sizes; LLAMA_BENCH_N overrides N.

use llama::coordinator::bench::Opts;

fn opts() -> Opts {
    let mut o = if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    };
    if let Ok(n) = std::env::var("LLAMA_BENCH_N") {
        o.n = n.parse().ok();
    }
    o
}

fn main() {
    let o = opts();
    let (update, mv) = llama::coordinator::fig5_nbody::run(&o);
    println!("{}", update.to_text());
    println!("{}", mv.to_text());
    // The paper's zero-overhead claim, asserted: LLAMA within 15% of
    // its manual twin (fig 5 shows ~1.00; margin for timer noise).
    let ms = |name: &str, t: &llama::coordinator::Table| -> f64 {
        t.rows
            .iter()
            .find(|r| r[0] == name)
            .unwrap_or_else(|| panic!("{name} row missing"))[1]
            .parse()
            .unwrap()
    };
    let manual = ms("manual AoS", &update);
    let llama_aos = ms("LLAMA AoS (aligned)", &update);
    let ratio = llama_aos / manual;
    println!("zero-overhead check (update AoS): LLAMA/manual = {ratio:.3}");
}
