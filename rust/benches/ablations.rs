//! `cargo bench --bench ablations` — ablation studies for the design
//! choices DESIGN.md calls out:
//!
//! 1. **Affine cursors on/off** — the zero-overhead fast path vs the
//!    generic accessor path on the n-body move sweep (EXPERIMENTS.md
//!    §Perf L3.1).
//! 2. **Chunk traversal order** — read- vs write-contiguous aosoa_copy
//!    across lane-count gaps (paper §4.2's (r)/(w) asymmetry).
//! 3. **AoSoA lane-count sweep** — the locality/vectorization sweet
//!    spot of paper §4.3/fig 8.
//! 4. **Split group count** — 2/4/8-way trace-derived hot/cold splits
//!    on the lbm step.

use llama::coordinator::bench::{bench, black_box, Opts};
use llama::coordinator::report::{fmt_ms, fmt_ratio, Table};
use llama::prelude::*;
use llama::workloads::nbody::{self, llama_impl};

fn opts() -> Opts {
    if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    }
}

/// Ablation 1: cursors vs generic accessors. The generic path is
/// forced by wrapping the mapping in Trace-like indirection — here we
/// use a newtype that hides `affine_leaves`.
struct NoAffine<M: Mapping>(M);

impl<M: Mapping> Mapping for NoAffine<M> {
    fn info(&self) -> &std::sync::Arc<RecordInfo> {
        self.0.info()
    }
    fn dims(&self) -> &ArrayDims {
        self.0.dims()
    }
    fn blob_count(&self) -> usize {
        self.0.blob_count()
    }
    fn blob_size(&self, nr: usize) -> usize {
        self.0.blob_size(nr)
    }
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.0.slot_of_lin(lin)
    }
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.0.slot_of_nd(idx)
    }
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        self.0.blob_nr_and_offset(leaf, slot)
    }
    fn mapping_name(&self) -> String {
        format!("NoAffine({})", self.0.mapping_name())
    }
    // affine_leaves: default None — the ablation.
}

fn ablation_cursors(o: &Opts) -> Table {
    let n = if o.quick { 1 << 18 } else { 1 << 22 };
    let reps = 8;
    let d = nbody::particle_dim();
    let state = nbody::init_particles(n, 3);
    let mut t = Table::new(
        format!("ablation 1: affine cursors on/off (move, N={n})"),
        &["case", "ms", "speedup"],
    );
    let mut rows = Vec::new();
    macro_rules! case {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state);
            let r = bench($name, 1, o.iters, || {
                for _ in 0..reps {
                    llama_impl::mv(&mut v);
                }
                black_box(v.blobs());
            });
            rows.push((($name).to_string(), r.median_ns));
        }};
    }
    case!("SoA MB + cursors", SoA::multi_blob(&d, ArrayDims::linear(n)));
    case!("SoA MB generic", NoAffine(SoA::multi_blob(&d, ArrayDims::linear(n))));
    case!("AoS + cursors", AoS::aligned(&d, ArrayDims::linear(n)));
    case!("AoS generic", NoAffine(AoS::aligned(&d, ArrayDims::linear(n))));
    for (name, ns) in &rows {
        // speedup of each generic row vs its cursor partner
        let partner = rows
            .iter()
            .find(|(n2, _)| n2 != name && n2.split(' ').next() == name.split(' ').next());
        let ratio =
            partner.map(|(_, p)| format!("{:.2}x", ns.max(*p) / ns.min(*p))).unwrap_or_default();
        t.row(vec![name.clone(), fmt_ms(*ns), ratio]);
    }
    t
}

fn ablation_chunk_order(o: &Opts) -> Table {
    use llama::copy::{aosoa_copy, ChunkOrder};
    let n = if o.quick { 1 << 16 } else { 1 << 20 };
    let d = nbody::particle_dim();
    let state = nbody::init_particles(n, 5);
    let mut t = Table::new(
        format!("ablation 2: chunk traversal order (N={n})"),
        &["pair", "read-contig ms", "write-contig ms"],
    );
    for (src_l, dst_l) in [(8usize, 512usize), (512, 8), (32, 32)] {
        let mut src = alloc_view(AoSoA::new(&d, ArrayDims::linear(n), src_l));
        llama_impl::load_state(&mut src, &state);
        let mut dst = alloc_view(AoSoA::new(&d, ArrayDims::linear(n), dst_l));
        let r = bench("r", 1, o.iters, || {
            aosoa_copy(&src, &mut dst, ChunkOrder::ReadContiguous);
            black_box(dst.blobs());
        });
        let w = bench("w", 1, o.iters, || {
            aosoa_copy(&src, &mut dst, ChunkOrder::WriteContiguous);
            black_box(dst.blobs());
        });
        t.row(vec![
            format!("AoSoA{src_l} -> AoSoA{dst_l}"),
            fmt_ms(r.median_ns),
            fmt_ms(w.median_ns),
        ]);
    }
    t
}

fn ablation_lanes(o: &Opts) -> Table {
    let n = if o.quick { 512 } else { 2048 };
    let d = nbody::particle_dim();
    let state = nbody::init_particles(n, 9);
    let mut t = Table::new(
        format!("ablation 3: AoSoA lane sweep (update, N={n}, blocked iteration)"),
        &["lanes", "ms", "vs lanes=8"],
    );
    let mut rows = Vec::new();
    for lanes in [2usize, 4, 8, 16, 32, 64, 128] {
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(n), lanes));
        llama_impl::load_state(&mut v, &state);
        let r = bench(&format!("L{lanes}"), 1, o.iters, || {
            llama_impl::update_blocked(&mut v, lanes);
            black_box(v.blobs());
        });
        rows.push((lanes, r.median_ns));
    }
    let base = rows.iter().find(|(l, _)| *l == 8).unwrap().1;
    for (lanes, ns) in rows {
        t.row(vec![lanes.to_string(), fmt_ms(ns), fmt_ratio(ns, base)]);
    }
    t
}

fn ablation_split_groups(o: &Opts) -> Table {
    use llama::workloads::lbm::split4::build_split4;
    use llama::workloads::lbm::step::{init, step};
    use llama::workloads::lbm::{cell_dim, Geometry};

    let g = if o.quick { 12 } else { 32 };
    let geo = Geometry::channel_with_sphere(g, g, g, 7);
    let d = cell_dim();
    let groups4 = llama::coordinator::fig8_lbm::trace_derived_groups(&geo);
    // 2-way: merge pairs of the 4 groups; 8-way: not supported by the
    // nested type — compare 2 vs 4 plus plain AoS.
    let groups2 = vec![
        groups4[0].iter().chain(&groups4[1]).copied().collect::<Vec<_>>(),
        groups4[2].iter().chain(&groups4[3]).copied().collect::<Vec<_>>(),
    ];
    let mut t = Table::new(
        format!("ablation 4: split granularity (lbm, grid {g}^3)"),
        &["mapping", "ms", "vs AoS"],
    );
    let mut rows = Vec::new();
    macro_rules! case {
        ($name:expr, $m0:expr, $m1:expr) => {{
            let mut a = alloc_view($m0);
            let mut b = alloc_view($m1);
            init(&mut a, &geo);
            init(&mut b, &geo);
            let r = bench($name, 1, o.iters, || {
                for _ in 0..2 {
                    step(&a, &mut b);
                    std::mem::swap(&mut a, &mut b);
                }
                black_box(a.blobs());
            });
            rows.push((($name).to_string(), r.median_ns));
        }};
    }
    case!("AoS", AoS::aligned(&d, geo.dims.clone()), AoS::aligned(&d, geo.dims.clone()));
    case!(
        "Split 2-way",
        Split::by_selectors(
            &d,
            geo.dims.clone(),
            groups2[0]
                .iter()
                .map(|&l| RecordInfo::new(&d).fields[l].coord.clone())
                .collect(),
            |sd, ad| AoS::aligned(sd, ad),
            |sd, ad| AoS::aligned(sd, ad),
        ),
        Split::by_selectors(
            &d,
            geo.dims.clone(),
            groups2[0]
                .iter()
                .map(|&l| RecordInfo::new(&d).fields[l].coord.clone())
                .collect(),
            |sd, ad| AoS::aligned(sd, ad),
            |sd, ad| AoS::aligned(sd, ad),
        )
    );
    case!(
        "Split 4-way",
        build_split4(&d, geo.dims.clone(), &groups4),
        build_split4(&d, geo.dims.clone(), &groups4)
    );
    let base = rows[0].1;
    for (name, ns) in rows {
        t.row(vec![name, fmt_ms(ns), fmt_ratio(ns, base)]);
    }
    t
}

fn main() {
    let o = opts();
    println!("{}", ablation_cursors(&o).to_text());
    println!("{}", ablation_chunk_order(&o).to_text());
    println!("{}", ablation_lanes(&o).to_text());
    println!("{}", ablation_split_groups(&o).to_text());
}
