//! `cargo bench --bench fig7_copy` — regenerates paper fig 7:
//! layout-changing copy throughput (naive / std::copy / aosoa_copy
//! r+w / parallel / memcpy) for 7-float particles and 100-field events.

use llama::coordinator::bench::Opts;

fn main() {
    let mut o = if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    };
    if let Ok(n) = std::env::var("LLAMA_BENCH_N") {
        o.n = n.parse().ok();
    }
    let t = llama::coordinator::fig7_copy::run(&o);
    println!("{}", t.to_text());
    let (naive, chunked, program) = llama::coordinator::fig7_copy::headline(&o);
    println!(
        "headline (SoA MB -> AoSoA32): aosoa_copy is {:.2}x, precompiled program {:.2}x \
         the naive copy",
        naive / chunked,
        naive / program
    );
}
