//! `cargo bench --bench fig6_xla_nbody` — regenerates paper fig 6
//! (hardware-adapted): n-body through the JAX/Pallas AOT artifacts on
//! the PJRT CPU client. Requires `make artifacts`.

use llama::coordinator::bench::Opts;

fn main() {
    let mut o = if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    };
    if let Ok(dir) = std::env::var("LLAMA_ARTIFACTS") {
        o.artifacts = dir;
    }
    match llama::coordinator::fig6_xla::verify_against_rust(&o) {
        Ok(rel) => {
            println!("stack correctness: max rel err = {rel:.2e}");
            assert!(rel < 1e-4);
            let t = llama::coordinator::fig6_xla::run(&o).expect("fig6");
            println!("{}", t.to_text());
        }
        Err(e) => println!("fig6 skipped ({e}); run `make artifacts` first"),
    }
}
