//! `cargo bench --bench fig8_lbm` — regenerates paper fig 8: the
//! 619.lbm_s analog across layouts, saturated (all threads) and
//! single-threaded. Env: LLAMA_BENCH_QUICK, LLAMA_BENCH_N (grid edge).

use llama::coordinator::bench::Opts;

fn main() {
    let mut o = if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    };
    if let Ok(n) = std::env::var("LLAMA_BENCH_N") {
        o.n = n.parse().ok();
    }
    for t in llama::coordinator::fig8_lbm::run(&o) {
        println!("{}", t.to_text());
    }
}
