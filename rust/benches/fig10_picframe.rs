//! `cargo bench --bench fig10_picframe` — regenerates paper fig 10:
//! PIConGPU-style particle-frame sweep across attribute layouts.
//! Env: LLAMA_BENCH_QUICK, LLAMA_BENCH_N (particles per supercell).

use llama::coordinator::bench::Opts;

fn main() {
    let mut o = if std::env::var("LLAMA_BENCH_QUICK").is_ok() {
        Opts::quick()
    } else {
        Opts::default()
    };
    if let Ok(n) = std::env::var("LLAMA_BENCH_N") {
        o.n = n.parse().ok();
    }
    let t = llama::coordinator::fig10_picframe::run(&o);
    println!("{}", t.to_text());
}
