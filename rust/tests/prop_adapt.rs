//! Property harness for the adaptive relayout engine (EXPERIMENTS.md
//! §Adapt): (1) migration through the engine's cached, sharded
//! program path is bit-identical to the `copy_naive` oracle for every
//! advisor-reachable recipe over the 13-mapping matrix; (2) advisor
//! idempotence — re-running the advisor on the post-migration layout
//! with the same stats recommends staying put (hysteresis holds, a
//! stable workload never re-migrates); (3) an epoch boundary leaves
//! zero counts behind.

mod prop_support;

use llama::mapping::RecipeMapping;
use llama::prelude::*;
use llama::view::adapt::{AdaptiveConfig, AdaptiveView};
use llama::workloads::lbm;
use llama::workloads::nbody::{self, llama_impl};
use llama::workloads::rng::SplitMix64;
use prop_support::*;

/// The 13-mapping matrix of `prop_copy_matrix.rs` (explicit layouts,
/// aliasing One, Split compositions, instrumented and represented
/// wrappers) — every one a possible *starting* layout for the engine.
const MATRIX: usize = 13;

fn nth(d: &RecordDim, dims: &ArrayDims, k: usize) -> Box<dyn Mapping> {
    match k {
        0 => Box::new(AoS::aligned(d, dims.clone())),
        1 => Box::new(AoS::packed(d, dims.clone())),
        2 => Box::new(SoA::single_blob(d, dims.clone())),
        3 => Box::new(SoA::multi_blob(d, dims.clone())),
        4 => Box::new(AoSoA::new(d, dims.clone(), 2)),
        5 => Box::new(AoSoA::new(d, dims.clone(), 4)),
        6 => Box::new(AoSoA::new(d, dims.clone(), 8)),
        7 => Box::new(AoSoA::new(d, dims.clone(), 16)),
        8 => Box::new(One::new(d, dims.clone())),
        9 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        )),
        10 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 8),
        )),
        11 => Box::new(Byteswap::new(AoS::packed(d, dims.clone()))),
        12 => Box::new(Heatmap::with_granularity(AoS::packed(d, dims.clone()), 4)),
        _ => unreachable!("matrix has {MATRIX} entries"),
    }
}

/// Every recipe shape the advisor can emit for the 7-leaf particle
/// record: plain AoS, plain SoA, and hot/cold splits with contiguous,
/// interleaved, and degenerate hot sets.
fn reachable_recipes() -> Vec<Recommendation> {
    vec![
        Recommendation::Aos,
        Recommendation::SoaMultiBlob,
        Recommendation::SplitHotCold { hot: vec![0, 1, 2] },
        Recommendation::SplitHotCold { hot: vec![1] },
        Recommendation::SplitHotCold { hot: vec![0, 2, 4, 6] },
        Recommendation::SplitHotCold { hot: vec![] },
        Recommendation::SplitHotCold { hot: (0..7).collect() },
    ]
}

/// (1) The engine's migration path — `ProgramCache::copy_parallel`,
/// plan-aligned shards, scoped threads — is bit-identical to the
/// `copy_naive` oracle for every (matrix start, reachable recipe)
/// pair, at tail-block extents, and repeated migrations between the
/// same pair compile exactly once.
#[test]
fn prop_engine_migration_matches_naive_for_every_reachable_recipe() {
    let d = nbody::particle_dim();
    for dims in [ArrayDims::linear(13), ArrayDims::linear(97)] {
        for k in 0..MATRIX {
            let cache = ProgramCache::new();
            let mut compiled_max = 0usize;
            for (r, rec) in reachable_recipes().into_iter().enumerate() {
                let mut src = alloc_view(nth(&d, &dims, k));
                fill_sentinels(&mut src);
                let target = rec.to_mapping(&d, dims.clone());
                let mut oracle = alloc_view(target.clone());
                copy_naive(&src, &mut oracle);
                for round in 0..2 {
                    let mut got = alloc_view(target.clone());
                    cache.copy_parallel(&src, &mut got, Some(3));
                    assert_eq!(
                        got.blobs(),
                        oracle.blobs(),
                        "start {k} recipe {r} round {round} ({dims:?})"
                    );
                }
                compiled_max = compiled_max.max(cache.entries());
            }
            // Cacheable pairs compiled once despite two rounds each;
            // generic starts (One is affine but Trace-like wrappers are
            // not) simply never enter the cache.
            assert!(cache.hits() >= compiled_max, "no reuse for start {k}");
        }
    }
    // Above PAR_MIN_RECORDS the cached path really shards: a reduced
    // start set (affine, SoA, AoSoA, piecewise Split, Byteswap) at a
    // tail-block extent, threads 3 and 7, still byte-equal to naive.
    let dims = ArrayDims::linear(4096 + 17);
    for k in [0usize, 3, 6, 9, 11] {
        let cache = ProgramCache::new();
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        for rec in [Recommendation::SoaMultiBlob, Recommendation::SplitHotCold { hot: vec![1] }] {
            let target = rec.to_mapping(&d, dims.clone());
            let mut oracle = alloc_view(target.clone());
            copy_naive(&src, &mut oracle);
            for threads in [3usize, 7] {
                let mut got = alloc_view(target.clone());
                cache.copy_parallel(&src, &mut got, Some(threads));
                assert_eq!(got.blobs(), oracle.blobs(), "start {k} threads {threads} (sharded)");
            }
        }
    }
}

/// (2) Advisor idempotence at the engine level: with re-sampling on
/// every other step, a stable workload migrates at most once and the
/// post-migration recommendation matches the live layout.
#[test]
fn prop_hysteresis_holds_under_resampling() {
    struct Move;
    impl AdaptiveKernel for Move {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut llama::view::View<M, B>) {
            llama_impl::mv(v);
        }
    }
    let d = nbody::particle_dim();
    let n = 96;
    let state = nbody::init_particles(n, 11);
    for start in 0..MATRIX {
        // Byteswap stores a foreign representation; the engine would
        // migrate it too, but llama_impl::load_state/mv only exercise
        // native layouts in this property.
        let mut v = alloc_view(nth(&d, &ArrayDims::linear(n), start));
        llama_impl::load_state(&mut v, &state);
        let cfg = AdaptiveConfig { steady_steps: 1, ..Default::default() };
        let mut av = AdaptiveView::new(v, cfg);
        for _ in 0..10 {
            av.step(&mut Move);
        }
        assert!(
            av.migrations() <= 1,
            "start {start}: {} migrations (hysteresis broken)",
            av.migrations()
        );
        // The layout the engine sits on is the one the advisor names.
        if let Some(rec) = av.advised() {
            let expect = rec.to_mapping(&d, ArrayDims::linear(n)).mapping_name();
            assert_eq!(av.mapping_name(), expect, "start {start}");
        }
        // Pure-function idempotence: same stats -> same verdict.
        let stats = FieldStats {
            fields: (0..7).map(|l| (l, if l == 6 { 0 } else { 100 }, 4)).collect(),
        };
        let info = RecordInfo::new(&d);
        let first = recommend_stats(&stats, &info, AccessPattern::Streaming);
        assert_eq!(first, recommend_stats(&stats, &info, AccessPattern::Streaming));
    }
}

/// (3) Epoch boundaries leave zero counts: after `snapshot()`, every
/// live Trace counter (and Heatmap granule) reads zero, across random
/// record dims and mappings.
#[test]
fn prop_epoch_reset_leaves_zero_counts() {
    for seed in 0..cases() / 2 {
        let mut rng = SplitMix64::new(seed ^ 0xADA9);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let leaves = dim.leaf_count();
        let mut t = Trace::new(gen_mapping(&mut rng, &dim, &dims));
        let mut h = Heatmap::new(gen_mapping(&mut rng, &dim, &dims));
        let n = dims.count();
        let touches = rng.below(50);
        for _ in 0..touches {
            let leaf = rng.below(leaves);
            let lin = rng.below(n);
            let _ = t.blob_nr_and_offset(leaf, t.inner().slot_of_lin(lin));
            let _ = h.blob_nr_and_offset(leaf, h.inner().slot_of_lin(lin));
        }
        let tsnap = t.snapshot();
        let hsnap = h.snapshot();
        assert!((0..leaves).all(|l| t.count(l) == 0), "seed {seed}: trace counts survive");
        assert_eq!(h.total(), 0, "seed {seed}: heatmap counts survive");
        // The snapshot kept exactly what the live counters dropped
        // (Heatmap counts one per touched granule: >= one per access).
        assert_eq!(tsnap.total(), touches as u64, "seed {seed}");
        assert!(hsnap.total() >= touches as u64, "seed {seed}");
        // A second boundary straight after is all-zero.
        assert_eq!(t.snapshot().total(), 0, "seed {seed}");
        assert_eq!(h.snapshot().total(), 0, "seed {seed}");
    }
}

/// (4) Blob-generality of the engine (EXPERIMENTS.md §Alloc): for
/// every matrix starting layout, an engine whose blobs live in a
/// `BlobPool` runs the same steps as the `Vec<u8>` engine and lands on
/// the same layout with **byte-identical** blobs — the pool's
/// zero-on-reuse rule (skip only under the compiled program's
/// full-coverage proof) makes recycled storage unobservable. And the
/// migration path of a *warmed* engine performs zero fresh blob
/// allocations, asserted via `PoolStats`.
#[test]
fn prop_pooled_engine_bit_identical_and_zero_alloc_when_warm() {
    struct Move;
    impl AdaptiveKernel for Move {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut llama::view::View<M, B>) {
            llama_impl::mv(v);
        }
    }
    let d = nbody::particle_dim();
    let n = 96;
    let state = nbody::init_particles(n, 11);
    let dims = ArrayDims::linear(n);
    for start in 0..MATRIX {
        // Reference: the Vec<u8> engine.
        let mut vec_view = alloc_view(nth(&d, &dims, start));
        llama_impl::load_state(&mut vec_view, &state);
        let mut vec_av = AdaptiveView::new(vec_view, AdaptiveConfig::default());
        for _ in 0..4 {
            vec_av.step(&mut Move);
        }
        let vec_final = vec_av.into_view();

        // Pooled engine, same start: seed the pooled start view with
        // the Vec view's exact bytes.
        let pool = BlobPool::new();
        let run_round = |pool: &BlobPool| {
            let mut seed_view = alloc_view(nth(&d, &dims, start));
            llama_impl::load_state(&mut seed_view, &state);
            let blobs: Vec<PooledBytes> = seed_view
                .blobs()
                .iter()
                .map(|b| {
                    let mut pb = pool.allocate(b.len());
                    pb.as_bytes_mut().copy_from_slice(b);
                    pb
                })
                .collect();
            let pooled_view = llama::view::View::from_blobs(nth(&d, &dims, start), blobs);
            let mut av =
                AdaptiveView::with_recycler(pooled_view, AdaptiveConfig::default(), pool.clone());
            for _ in 0..4 {
                av.step(&mut Move);
            }
            av.into_view()
        };
        let pooled_final = run_round(&pool);
        assert_eq!(
            pooled_final.mapping().mapping_name(),
            vec_final.mapping().mapping_name(),
            "start {start}: engines diverged on layout"
        );
        assert_eq!(
            pooled_final.blobs().len(),
            vec_final.blobs().len(),
            "start {start}: blob count"
        );
        for (nr, (p, v)) in pooled_final.blobs().iter().zip(vec_final.blobs()).enumerate() {
            assert_eq!(
                p.as_bytes(),
                v.as_slice(),
                "start {start} blob {nr}: pooled bytes != Vec<u8> bytes"
            );
        }

        // Warm round: every blob the engine needs is on a free list,
        // so the whole observe→migrate cycle allocates nothing fresh.
        drop(pooled_final);
        let before = pool.stats();
        let again = run_round(&pool);
        let after = pool.stats();
        assert_eq!(
            after.misses, before.misses,
            "start {start}: warmed engine allocated fresh blobs"
        );
        for (nr, (p, v)) in again.blobs().iter().zip(vec_final.blobs()).enumerate() {
            assert_eq!(p.as_bytes(), v.as_slice(), "start {start} blob {nr} (warm round)");
        }
    }
}

/// The ISSUE acceptance scenario end-to-end: lbm starting from AoS —
/// the engine's trace epoch triggers exactly one migration to the
/// advisor's hot/cold Split, and the post-migration fields are
/// bit-identical to a fixed-layout reference run.
#[test]
fn lbm_adaptive_end_to_end_migrates_to_split_and_stays_correct() {
    struct Step;
    impl AdaptiveKernel2 for Step {
        fn run<M: Mapping, B: BlobMut + Sync>(
            &mut self,
            src: &llama::view::View<M, B>,
            dst: &mut llama::view::View<M, B>,
        ) {
            lbm::step::step(src, dst);
        }
    }
    let geo = lbm::Geometry::channel_with_sphere(6, 6, 6, 3);
    let d = lbm::cell_dim();
    let steps = 4;

    // Reference: the same steps on plain AoS (the step kernel is
    // bit-identical across layouts — asserted by the lbm unit tests).
    let mut a = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    let mut b = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm::step::init(&mut a, &geo);
    lbm::step::init(&mut b, &geo);
    for _ in 0..steps {
        lbm::step::step(&a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }

    let mut v = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm::step::init(&mut v, &geo);
    let mut av = AdaptiveView::new(v, AdaptiveConfig { steady_steps: 0, ..Default::default() });
    for _ in 0..steps {
        av.step_zip(&mut Step);
    }
    assert_eq!(av.migrations(), 1, "trace epoch must trigger exactly one migration");
    assert!(
        av.mapping_name().starts_with("Split("),
        "expected the advisor's hot/cold Split, got {}",
        av.mapping_name()
    );
    for lin in 0..geo.dims.count() {
        for leaf in [0usize, 9, lbm::FLAGS] {
            assert_eq!(
                av.get::<f64>(lin, leaf),
                a.get::<f64>(lin, leaf),
                "cell {lin} leaf {leaf} diverged after migration"
            );
        }
    }
    // The adopted Split behaves like a first-class mapping: one more
    // reference step on it reproduces the AoS result again.
    let split_view = av.into_view();
    let (mapping, blobs) = split_view.into_parts();
    let back: llama::view::View<RecipeMapping, Vec<u8>> =
        llama::view::View::from_blobs(mapping.clone(), blobs);
    let mut next = alloc_view(mapping);
    lbm::step::step(&back, &mut next);
    let mut a2 = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm::step::step(&a, &mut a2);
    for lin in 0..geo.dims.count() {
        assert_eq!(next.get::<f64>(lin, 5), a2.get::<f64>(lin, 5), "cell {lin}");
    }
}
