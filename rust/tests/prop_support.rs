//! Self-contained property-testing support (proptest is not in the
//! vendored crate set): a deterministic case generator over random
//! record dimensions, array dimensions and mappings, plus shrink-free
//! exhaustive-ish iteration. Each property runs [`cases`] generated
//! cases (env-tunable); failures print the seed for replay.

// Included via `mod prop_support;` by several test crates, none of
// which uses every helper.
#![allow(dead_code)]

use llama::prelude::*;
use llama::workloads::rng::SplitMix64;

/// Generated cases per property: 60 by default (PR-sized), raised via
/// the `LLAMA_PROPTEST_CASES` env knob (the scheduled CI `test-matrix`
/// job sets it to several hundred). Invalid values fall back to the
/// default rather than silently running zero cases.
pub fn cases() -> u64 {
    static CASES: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    *CASES.get_or_init(|| {
        std::env::var("LLAMA_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(60)
    })
}

/// Generate a random record dimension: 1..=10 fields, nesting depth up
/// to 3, arrays up to 4 elements, all scalar kinds.
pub fn gen_record_dim(rng: &mut SplitMix64) -> RecordDim {
    fn gen_type(rng: &mut SplitMix64, depth: usize) -> Type {
        let scalars = [
            Scalar::F32,
            Scalar::F64,
            Scalar::I8,
            Scalar::I16,
            Scalar::I32,
            Scalar::I64,
            Scalar::U8,
            Scalar::U16,
            Scalar::U32,
            Scalar::U64,
            Scalar::Bool,
        ];
        let pick = rng.below(if depth >= 3 { 10 } else { 14 });
        match pick {
            0..=9 => Type::Scalar(scalars[rng.below(scalars.len())]),
            10 | 11 => {
                let n = 1 + rng.below(3);
                let fields = (0..n)
                    .map(|i| Field::new(format!("f{i}"), gen_type(rng, depth + 1)))
                    .collect();
                Type::Record(fields)
            }
            _ => {
                let n = 1 + rng.below(4);
                Type::Array(Box::new(gen_type(rng, depth + 1)), n)
            }
        }
    }
    let nfields = 1 + rng.below(6);
    RecordDim {
        fields: (0..nfields)
            .map(|i| Field::new(format!("top{i}"), gen_type(rng, 1)))
            .collect(),
    }
}

/// Generate random array dimensions with a bounded record count.
pub fn gen_dims(rng: &mut SplitMix64) -> ArrayDims {
    match rng.below(3) {
        0 => ArrayDims::linear(1 + rng.below(40)),
        1 => ArrayDims::from([1 + rng.below(8), 1 + rng.below(8)]),
        _ => ArrayDims::from([1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4)]),
    }
}

/// All storage mappings (injective; instrumentation wrappers excluded),
/// type-erased for uniform testing.
pub fn gen_mapping(rng: &mut SplitMix64, dim: &RecordDim, dims: &ArrayDims) -> Box<dyn Mapping> {
    let k = rng.below(10);
    match k {
        0 => Box::new(AoS::aligned(dim, dims.clone())),
        1 => Box::new(AoS::packed(dim, dims.clone())),
        2 => Box::new(SoA::multi_blob(dim, dims.clone())),
        3 => Box::new(SoA::single_blob(dim, dims.clone())),
        4 | 5 => {
            let lanes = [1, 2, 3, 4, 8, 16, 32][rng.below(7)];
            Box::new(AoSoA::new(dim, dims.clone(), lanes))
        }
        6 => Box::new(AoS::with_linearizer(dim, dims.clone(), MortonCurve, false)),
        7 => Box::new(SoA::with_linearizer(dim, dims.clone(), ColMajor, true)),
        8 if dim.leaf_count() >= 2 => {
            // Split at a random top-level field.
            let sel = RecordCoord::new(vec![rng.below(dim.fields.len())]);
            let inner = rng.below(2) == 0;
            if inner {
                Box::new(Split::new(
                    dim,
                    dims.clone(),
                    sel,
                    |d, ad| SoA::multi_blob(d, ad),
                    |d, ad| AoS::aligned(d, ad),
                ))
            } else {
                Box::new(Split::new(
                    dim,
                    dims.clone(),
                    sel,
                    |d, ad| AoS::packed(d, ad),
                    |d, ad| SoA::single_blob(d, ad),
                ))
            }
        }
        _ => Box::new(AoS::aligned(dim, dims.clone())),
    }
}

/// Write a distinct sentinel into every (leaf, lin); returns a closure
/// reproducing the expected bytes for verification.
pub fn sentinel_bytes(leaf: usize, lin: usize, size: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new((leaf as u64) << 32 | lin as u64 | 0xABCD_0000_0000_0000);
    (0..size).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
}

pub fn fill_sentinels<M: Mapping, B: BlobMut>(view: &mut llama::view::View<M, B>) {
    let info = view.mapping().info().clone();
    for lin in 0..view.count() {
        for leaf in 0..info.leaf_count() {
            let bytes = sentinel_bytes(leaf, lin, info.fields[leaf].size());
            let (mapping, blobs) = view.mapping_and_blobs_mut();
            let slot = mapping.slot_of_lin(lin);
            let (nr, off) = mapping.blob_nr_and_offset(leaf, slot);
            blobs[nr].as_bytes_mut()[off..off + bytes.len()].copy_from_slice(&bytes);
        }
    }
}
