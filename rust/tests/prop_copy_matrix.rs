//! Differential copy-oracle matrix (DESIGN.md §8d, EXPERIMENTS.md
//! §Copy): every (src, dst) layout pair from the explicit matrix —
//! AoS (aligned/packed), SoA (SB/MB), AoSoA{2,4,8,16}, One, Split
//! compositions, Byteswap, Heatmap — across both `ChunkOrder`s and
//! tail-block extents, asserting that compiled `CopyProgram` execution
//! is **bit-identical** to the `copy_naive` oracle, that the
//! dispatcher picks the expected `CopyMethod` for every pair with no
//! panic path, and that sharded parallel execution reproduces the
//! serial bytes at any thread count.

mod prop_support;

use llama::copy::program::shard_programs;
use llama::copy::{aosoa_compatible, aosoa_copy, copy_aosoa_parallel, copy_naive_parallel};
use llama::copy::{
    layouts_identical, plans_chunk_compatible, plans_strided_compatible, plans_swap_compatible,
};
use llama::prelude::*;
use llama::workloads::nbody;
use llama::workloads::rng::SplitMix64;
use prop_support::*;

/// Explicit layout matrix; index 8 is the aliasing `One` mapping.
const MATRIX: usize = 13;
const ONE_IDX: usize = 8;

fn nth(d: &RecordDim, dims: &ArrayDims, k: usize) -> Box<dyn Mapping> {
    match k {
        0 => Box::new(AoS::aligned(d, dims.clone())),
        1 => Box::new(AoS::packed(d, dims.clone())),
        2 => Box::new(SoA::single_blob(d, dims.clone())),
        3 => Box::new(SoA::multi_blob(d, dims.clone())),
        4 => Box::new(AoSoA::new(d, dims.clone(), 2)),
        5 => Box::new(AoSoA::new(d, dims.clone(), 4)),
        6 => Box::new(AoSoA::new(d, dims.clone(), 8)),
        7 => Box::new(AoSoA::new(d, dims.clone(), 16)),
        8 => Box::new(One::new(d, dims.clone())),
        9 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        )),
        10 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 8),
        )),
        11 => Box::new(Byteswap::new(AoS::packed(d, dims.clone()))),
        12 => Box::new(Heatmap::with_granularity(AoS::packed(d, dims.clone()), 4)),
        _ => unreachable!("matrix has {MATRIX} entries"),
    }
}

/// Extents chosen so every lane count in the matrix sees tail blocks
/// (13 and 97 are prime; 35 = 5*7 is multi-dimensional).
fn extents() -> Vec<ArrayDims> {
    vec![
        ArrayDims::linear(13),
        ArrayDims::linear(96),
        ArrayDims::linear(97),
        ArrayDims::from([5, 7]),
    ]
}

/// The documented strategy-selection rules, restated independently of
/// the dispatcher: identical → blobwise; equal representation and
/// chunkable → chunked; equal representation and affine → strided
/// program; representation-mismatched affine pair → swap program;
/// otherwise field-wise gather.
fn expected_method(src: &dyn Mapping, dst: &dyn Mapping) -> CopyMethod {
    let sp = src.plan();
    let dp = dst.plan();
    if layouts_identical(src, dst) {
        CopyMethod::Blobwise
    } else if plans_chunk_compatible(&sp, &dp) {
        CopyMethod::AoSoAChunked
    } else if plans_strided_compatible(&sp, &dp) {
        CopyMethod::Program
    } else if plans_swap_compatible(&sp, &dp) {
        CopyMethod::SwapProgram
    } else {
        CopyMethod::FieldWise
    }
}

/// The acceptance property: compiled `CopyProgram` execution is
/// bit-identical to the naive oracle for every pair in the matrix,
/// under both chunk traversal orders, at every tail-block extent.
/// (Destinations start zeroed, so even the padding bytes the blobwise
/// strategy copies compare equal.)
#[test]
fn prop_program_execution_matches_the_naive_oracle() {
    let d = nbody::particle_dim();
    for dims in extents() {
        for i in 0..MATRIX {
            let mut src = alloc_view(nth(&d, &dims, i));
            fill_sentinels(&mut src);
            for j in 0..MATRIX {
                let mut oracle = alloc_view(nth(&d, &dims, j));
                copy_naive(&src, &mut oracle);
                let label = format!(
                    "{} -> {} ({dims:?})",
                    src.mapping().mapping_name(),
                    oracle.mapping().mapping_name()
                );
                for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
                    let prog =
                        CopyProgram::compile_ordered(src.mapping(), oracle.mapping(), order);
                    let mut got = alloc_view(nth(&d, &dims, j));
                    prog.execute(&src, &mut got);
                    assert_eq!(got.blobs(), oracle.blobs(), "{label} {order:?}");
                    if j != ONE_IDX {
                        assert!(views_equal(&src, &got), "{label} {order:?}");
                    }
                }
            }
        }
    }
}

/// The dispatcher picks the expected `CopyMethod` for every pair —
/// including the new `Program` variant for affine non-chunkable pairs
/// — with no panic path anywhere in the matrix, and its result is
/// bit-identical to the oracle.
#[test]
fn prop_dispatcher_picks_expected_method_without_panicking() {
    let d = nbody::particle_dim();
    for dims in [ArrayDims::linear(13), ArrayDims::from([5, 7])] {
        for i in 0..MATRIX {
            for j in 0..MATRIX {
                let src_m = nth(&d, &dims, i);
                let dst_m = nth(&d, &dims, j);
                let expect = expected_method(src_m.as_ref(), dst_m.as_ref());
                let mut src = alloc_view(src_m);
                fill_sentinels(&mut src);
                let mut dst = alloc_view(dst_m);
                let got = copy(&src, &mut dst);
                let label = format!(
                    "{} -> {} ({dims:?})",
                    src.mapping().mapping_name(),
                    dst.mapping().mapping_name()
                );
                assert_eq!(got, expect, "{label}");
                let mut oracle = alloc_view(nth(&d, &dims, j));
                copy_naive(&src, &mut oracle);
                assert_eq!(dst.blobs(), oracle.blobs(), "{label}");
            }
        }
    }
}

/// A few structural facts the matrix relies on (guards against the
/// matrix silently degenerating): all five strategies appear.
#[test]
fn matrix_covers_every_method() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(13);
    use CopyMethod::*;
    let method = |i: usize, j: usize| {
        expected_method(nth(&d, &dims, i).as_ref(), nth(&d, &dims, j).as_ref())
    };
    assert_eq!(method(5, 5), Blobwise); // AoSoA4 -> AoSoA4
    assert_eq!(method(3, 6), AoSoAChunked); // SoA MB -> AoSoA8
    assert_eq!(method(0, 3), Program); // aligned AoS -> SoA MB (strided)
    assert_eq!(method(11, 3), SwapProgram); // Byteswap -> SoA MB (affine pair)
    assert_eq!(method(11, 11), Blobwise); // Byteswap -> same Byteswap
    assert_eq!(method(11, 12), FieldWise); // Byteswap -> Heatmap (generic plan)
    assert_eq!(method(12, 12), Blobwise); // Heatmap -> same Heatmap
    assert_eq!(method(5, 10), AoSoAChunked); // AoSoA4 -> Split gcd pair
}

/// Satellite 2: sharded `CopyProgram` execution is bit-identical to
/// serial at thread counts 1/2/7 across strategy classes, and
/// aliasing destination plans (`One`) collapse to one sub-program.
#[test]
fn prop_parallel_copy_bit_identical_across_thread_counts() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(4096 + 17); // tail at every lane count
    // (chunked SoA->AoSoA16, chunked AoSoA8->AoSoA16, chunked
    // AoS->SoA, strided aligned-AoS->SoA, chunked into a gcd Split,
    // swap runs from a Byteswap source.)
    for (i, j) in [(3, 7), (6, 7), (1, 3), (0, 3), (5, 10), (11, 3)] {
        let mut src = alloc_view(nth(&d, &dims, i));
        fill_sentinels(&mut src);
        let mut serial = alloc_view(nth(&d, &dims, j));
        CopyProgram::compile(src.mapping(), serial.mapping()).execute(&src, &mut serial);
        for threads in [1usize, 2, 7] {
            let mut par = alloc_view(nth(&d, &dims, j));
            copy_parallel(&src, &mut par, Some(threads));
            assert_eq!(par.blobs(), serial.blobs(), "pair ({i},{j}) threads {threads}");
        }
    }
    // Aliasing destination: exactly one sub-program, and the parallel
    // entry point still produces the serial result (last record wins).
    let src_m = nth(&d, &dims, 3);
    let one = One::new(&d, dims.clone());
    assert_eq!(shard_programs(src_m.as_ref(), &one, 8).len(), 1);
    let mut src = alloc_view(src_m);
    fill_sentinels(&mut src);
    let mut serial = alloc_view(One::new(&d, dims.clone()));
    copy_naive(&src, &mut serial);
    let mut par = alloc_view(One::new(&d, dims.clone()));
    copy_parallel(&src, &mut par, Some(8));
    assert_eq!(par.blobs(), serial.blobs());
    // Real sharding happens where it is safe.
    let a16 = nth(&d, &dims, 7);
    let progs = shard_programs(src.mapping(), a16.as_ref(), 7);
    assert!(progs.len() > 1 && progs.len() <= 7, "{} sub-programs", progs.len());
}

/// Random record dims × extents × mapping pairs: every copy entry
/// point agrees with the oracle (the legacy random property, now with
/// the program paths included).
#[test]
fn prop_all_strategies_equal_on_random_pairs() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0xC0B1);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let src_m = gen_mapping(&mut rng, &dim, &dims);
        // Two structurally identical destination mappings from twin
        // rng streams: one for the oracle, one for the program paths.
        let mut twin_a = SplitMix64::new(seed ^ 0xD57);
        let mut twin_b = SplitMix64::new(seed ^ 0xD57);
        let dst_m = gen_mapping(&mut twin_a, &dim, &dims);
        let dst_m2 = gen_mapping(&mut twin_b, &dim, &dims);
        let label = format!("seed {seed}: {} -> {}", src_m.mapping_name(), dst_m.mapping_name());

        let mut src = alloc_view(src_m);
        fill_sentinels(&mut src);
        let mut oracle = alloc_view(dst_m);
        copy_naive(&src, &mut oracle);

        // stdcopy — fresh destination to catch missed writes.
        let mut dst = alloc_view(dst_m2);
        copy_stdcopy(&src, &mut dst);
        assert!(views_equal(&src, &dst), "{label} stdcopy");

        // parallel naive
        zero_blobs(&mut dst);
        copy_naive_parallel(&src, &mut dst, Some(4));
        assert_eq!(dst.blobs(), oracle.blobs(), "{label} naive(p)");

        // chunked variants where applicable
        if aosoa_compatible(src.mapping(), dst.mapping()) {
            for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
                zero_blobs(&mut dst);
                aosoa_copy(&src, &mut dst, order);
                assert_eq!(dst.blobs(), oracle.blobs(), "{label} aosoa {order:?}");
                zero_blobs(&mut dst);
                copy_aosoa_parallel(&src, &mut dst, order, Some(3));
                assert_eq!(dst.blobs(), oracle.blobs(), "{label} aosoa(p) {order:?}");
            }
        }

        // dispatcher + parallel dispatcher, both through the program
        zero_blobs(&mut dst);
        let method = copy(&src, &mut dst);
        assert_eq!(dst.blobs(), oracle.blobs(), "{label} dispatch {method:?}");
        zero_blobs(&mut dst);
        let method = copy_parallel(&src, &mut dst, Some(3));
        assert_eq!(dst.blobs(), oracle.blobs(), "{label} dispatch(p) {method:?}");
    }
}

fn zero_blobs<M: Mapping>(v: &mut llama::view::View<M, Vec<u8>>) {
    let (_, blobs) = v.mapping_and_blobs_mut();
    for b in blobs {
        b.fill(0);
    }
}

/// Chained copies across three layouts preserve the original data.
#[test]
fn prop_copy_chain_roundtrip() {
    for seed in 0..cases() / 2 {
        let mut rng = SplitMix64::new(seed ^ 0xCAA1);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let mut a = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        fill_sentinels(&mut a);
        let mut b = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        let mut c = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        copy(&a, &mut b);
        copy(&b, &mut c);
        assert!(views_equal(&a, &c), "seed {seed}: chain broke");
    }
}

/// Byteswap views interoperate with every other layout through the
/// dispatcher (value-preserving, never byte-copying).
#[test]
fn prop_byteswap_interop() {
    for seed in 0..cases() / 3 {
        let mut rng = SplitMix64::new(seed ^ 0xB5AA);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let mut swapped = alloc_view(Byteswap::new(AoS::packed(&dim, dims.clone())));
        fill_sentinels(&mut swapped);
        let mut native = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        copy(&swapped, &mut native);
        assert!(views_equal(&swapped, &native), "seed {seed}: swap -> native");
        let mut back = alloc_view(Byteswap::new(AoS::packed(&dim, dims.clone())));
        copy(&native, &mut back);
        assert!(views_equal(&swapped, &back), "seed {seed}: native -> swap");
    }
}
