//! Property tests of the copy engine (DESIGN.md §8d): for random
//! mapping pairs over the same data space and random data, every copy
//! strategy produces a field-wise-equal destination — and the
//! dispatcher always picks a valid strategy.

mod prop_support;

use llama::copy::{
    aosoa_compatible, aosoa_copy, copy, copy_aosoa_parallel, copy_naive, copy_naive_parallel,
    copy_stdcopy, views_equal, ChunkOrder,
};
use llama::prelude::*;
use llama::workloads::rng::SplitMix64;
use prop_support::*;

#[test]
fn prop_all_strategies_equal_on_random_pairs() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xC0B1);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let src_m = gen_mapping(&mut rng, &dim, &dims);
        let dst_m = gen_mapping(&mut rng, &dim, &dims);
        let label = format!(
            "seed {seed}: {} -> {}",
            src_m.mapping_name(),
            dst_m.mapping_name()
        );

        let mut src = alloc_view(src_m);
        fill_sentinels(&mut src);

        // naive
        let mut dst = alloc_view(dst_m);
        copy_naive(&src, &mut dst);
        assert!(views_equal(&src, &dst), "{label} naive");

        // stdcopy — fresh destination to catch missed writes.
        zero_blobs(&mut dst);
        copy_stdcopy(&src, &mut dst);
        assert!(views_equal(&src, &dst), "{label} stdcopy");

        // parallel naive
        zero_blobs(&mut dst);
        copy_naive_parallel(&src, &mut dst, Some(4));
        assert!(views_equal(&src, &dst), "{label} naive(p)");

        // chunked variants where applicable
        if aosoa_compatible(src.mapping(), dst.mapping()) {
            for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
                zero_blobs(&mut dst);
                aosoa_copy(&src, &mut dst, order);
                assert!(views_equal(&src, &dst), "{label} aosoa {order:?}");
                zero_blobs(&mut dst);
                copy_aosoa_parallel(&src, &mut dst, order, Some(3));
                assert!(views_equal(&src, &dst), "{label} aosoa(p) {order:?}");
            }
        }

        // dispatcher
        zero_blobs(&mut dst);
        let method = copy(&src, &mut dst);
        assert!(views_equal(&src, &dst), "{label} dispatch {method:?}");
    }
}

fn zero_blobs<M: Mapping>(v: &mut llama::view::View<M, Vec<u8>>) {
    let (_, blobs) = v.mapping_and_blobs_mut();
    for b in blobs {
        b.fill(0);
    }
}

/// Chained copies across three layouts preserve the original data.
#[test]
fn prop_copy_chain_roundtrip() {
    for seed in 0..CASES / 2 {
        let mut rng = SplitMix64::new(seed ^ 0xCAA1);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let mut a = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        fill_sentinels(&mut a);
        let mut b = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        let mut c = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        copy(&a, &mut b);
        copy(&b, &mut c);
        assert!(views_equal(&a, &c), "seed {seed}: chain broke");
    }
}

/// Byteswap views interoperate with every other layout through the
/// dispatcher (value-preserving, never byte-copying).
#[test]
fn prop_byteswap_interop() {
    for seed in 0..CASES / 3 {
        let mut rng = SplitMix64::new(seed ^ 0xB5AA);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let mut swapped = alloc_view(Byteswap::new(AoS::packed(&dim, dims.clone())));
        fill_sentinels(&mut swapped);
        let mut native = alloc_view(gen_mapping(&mut rng, &dim, &dims));
        copy(&swapped, &mut native);
        assert!(views_equal(&swapped, &native), "seed {seed}: swap -> native");
        let mut back = alloc_view(Byteswap::new(AoS::packed(&dim, dims.clone())));
        copy(&native, &mut back);
        assert!(views_equal(&swapped, &back), "seed {seed}: native -> swap");
    }
}
