//! Property tests for the plan-aligned view sharding subsystem
//! (`view::shard`, EXPERIMENTS.md §Parallel): shards are disjoint,
//! cover the full record range, respect the plan's lane alignment
//! across the whole mapping matrix (including tail blocks), and the
//! parallel workload drivers reproduce the single-thread results
//! bit-identically.

mod prop_support;

use llama::prelude::*;
use llama::workloads::nbody::{self, llama_impl};
use llama::workloads::rng::SplitMix64;
use prop_support::*;

/// The explicit layout matrix of the shard-soundness property:
/// AoS (aligned/packed), SoA (SB/MB), AoSoA{2,4,8,16}, One, and Split
/// compositions (piecewise-composing and gcd-chunking).
fn mapping_matrix(dim: &RecordDim, dims: &ArrayDims) -> Vec<Box<dyn Mapping>> {
    let mut out: Vec<Box<dyn Mapping>> = vec![
        Box::new(AoS::aligned(dim, dims.clone())),
        Box::new(AoS::packed(dim, dims.clone())),
        Box::new(SoA::single_blob(dim, dims.clone())),
        Box::new(SoA::multi_blob(dim, dims.clone())),
        Box::new(One::new(dim, dims.clone())),
    ];
    for lanes in [2usize, 4, 8, 16] {
        out.push(Box::new(AoSoA::new(dim, dims.clone(), lanes)));
    }
    if dim.fields.len() >= 2 {
        let sel = RecordCoord::new(vec![1]);
        out.push(Box::new(Split::new(
            dim,
            dims.clone(),
            sel.clone(),
            |d, ad| AoSoA::new(d, ad, 4),
            |d, ad| SoA::multi_blob(d, ad),
        )));
        out.push(Box::new(Split::new(
            dim,
            dims.clone(),
            sel,
            |d, ad| AoSoA::new(d, ad, 4),
            |d, ad| AoSoA::new(d, ad, 6),
        )));
    }
    out
}

fn check_shards(shards: &[Shard], count: usize, parts: usize, align: usize, label: &str) {
    assert!(shards.len() <= parts.max(1), "{label}: more shards than parts");
    let mut expect = 0usize;
    for s in shards {
        assert_eq!(s.start, expect, "{label}: gap/overlap at {s:?}");
        assert!(s.end > s.start, "{label}: empty shard {s:?}");
        assert_eq!(s.start % align, 0, "{label}: start of {s:?} not {align}-aligned");
        if s.end != count {
            assert_eq!(s.end % align, 0, "{label}: interior end of {s:?} not {align}-aligned");
        }
        expect = s.end;
    }
    assert_eq!(expect, count, "{label}: shards do not cover 0..{count}");
}

#[test]
fn prop_shards_disjoint_covering_and_lane_aligned() {
    let d = nbody::particle_dim();
    // Counts chosen to exercise tail blocks at every lane count in the
    // matrix (97 and 257 are prime, 13 < some lane counts).
    for count in [0usize, 1, 5, 13, 64, 97, 257] {
        let dims = ArrayDims::linear(count);
        for m in mapping_matrix(&d, &dims) {
            let plan = m.plan();
            let align = shard_align(&plan);
            // Piecewise plans must align to their lane count.
            if let AddrPlan::PiecewiseAoSoA(p) = plan.addr() {
                assert_eq!(align, p.lanes, "{}", m.mapping_name());
            }
            for parts in [1usize, 2, 3, 4, 8, 16] {
                let shards = shard_plan(&plan, parts);
                let label = format!("{} count {count} parts {parts}", m.mapping_name());
                check_shards(&shards, count, parts, align, &label);
            }
        }
    }
}

#[test]
fn prop_shards_on_random_mappings() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0x5AAD);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        let plan = m.plan();
        let align = shard_align(&plan);
        let parts = 1 + rng.below(8);
        let shards = shard_plan(&plan, parts);
        let label = format!("seed {seed}: {}", m.mapping_name());
        check_shards(&shards, dims.count(), parts, align, &label);
    }
}

#[test]
fn pair_align_lands_on_both_layouts() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(4096 + 17);
    let cases: Vec<(Box<dyn Mapping>, Box<dyn Mapping>, usize)> = vec![
        (
            Box::new(SoA::multi_blob(&d, dims.clone())),
            Box::new(AoSoA::new(&d, dims.clone(), 32)),
            32,
        ),
        (
            Box::new(AoSoA::new(&d, dims.clone(), 4)),
            Box::new(AoSoA::new(&d, dims.clone(), 6)),
            12,
        ),
        (
            Box::new(AoS::packed(&d, dims.clone())),
            Box::new(AoS::aligned(&d, dims.clone())),
            1,
        ),
    ];
    for (a, b, expect) in cases {
        let align = pair_align(&a.plan(), &b.plan());
        assert_eq!(align, expect, "{} x {}", a.mapping_name(), b.mapping_name());
        check_shards(
            &shard_range(dims.count(), 4, align),
            dims.count(),
            4,
            align,
            "pair",
        );
    }
}

/// The acceptance property of the refactor: running any workload over
/// shards (any thread count) is bit-identical to the single-thread
/// sweep — each record's arithmetic is self-contained, so sharding
/// changes scheduling, never results.
#[test]
fn parallel_nbody_is_bit_identical_across_layouts() {
    let n = 101; // tails at every lane count
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(n);
    let state = nbody::init_particles(n, 31);

    fn run<M: Mapping>(mapping: M, s: &nbody::ParticleSoA, threads: usize) -> nbody::ParticleSoA {
        let mut v = alloc_view(mapping);
        llama_impl::load_state(&mut v, s);
        llama_impl::update_parallel(&mut v, threads);
        llama_impl::mv_parallel(&mut v, threads);
        llama_impl::store_state(&v)
    }

    let expect = run(AoS::aligned(&d, dims.clone()), &state, 1);
    for threads in [1usize, 2, 5] {
        assert_eq!(expect, run(AoS::aligned(&d, dims.clone()), &state, threads));
        assert_eq!(expect, run(AoS::packed(&d, dims.clone()), &state, threads));
        assert_eq!(expect, run(SoA::multi_blob(&d, dims.clone()), &state, threads));
        assert_eq!(expect, run(SoA::single_blob(&d, dims.clone()), &state, threads));
        assert_eq!(expect, run(AoSoA::new(&d, dims.clone(), 8), &state, threads));
        assert_eq!(expect, run(AoSoA::new(&d, dims.clone(), 16), &state, threads));
        let split = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![0]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        );
        assert_eq!(expect, run(split, &state, threads));
    }
}

#[test]
fn parallel_lbm_is_bit_identical() {
    use llama::workloads::lbm::step::{init, step, step_parallel};
    use llama::workloads::lbm::{cell_dim, Geometry};
    let geo = Geometry::channel_with_sphere(6, 4, 4, 3);
    let d = cell_dim();
    let mut a = alloc_view(AoSoA::new(&d, geo.dims.clone(), 16));
    let mut serial = alloc_view(AoSoA::new(&d, geo.dims.clone(), 16));
    let mut par = alloc_view(AoSoA::new(&d, geo.dims.clone(), 16));
    init(&mut a, &geo);
    step(&a, &mut serial);
    for threads in [2usize, 3, 6] {
        step_parallel(&a, &mut par, threads);
        assert_eq!(serial.blobs(), par.blobs(), "threads {threads}");
    }
}

#[test]
fn parallel_hep_single_thread_is_exact() {
    use llama::workloads::hep::{generate_events, isolated_energy, isolated_energy_parallel};
    let d = llama::workloads::hep::event_dim();
    let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(77)));
    generate_events(&mut v, 13);
    let serial = isolated_energy(&v, 90);
    assert_eq!(isolated_energy_parallel(&v, 90, 1), serial);
    let par4 = isolated_energy_parallel(&v, 90, 4);
    assert!((par4 - serial).abs() / serial.abs().max(1.0) < 1e-9);
}
