//! Property harness for the concurrent serving layer (EXPERIMENTS.md
//! §Serve): (1) readers pinned to published generations observe only
//! *whole* generations — each one bit-identical to the corresponding
//! state of a serial `AdaptiveView` run, for every starting layout of
//! the 13-mapping matrix, verified from concurrent threads; (2) a
//! warmed pooled engine publishes and migrates with zero fresh blob
//! allocations, and the last unpin of a retired generation returns its
//! blobs to the pool; (3) an [`AdvisorPool`] cycle migrates *exactly*
//! the top-`budget` parked decisions by predicted gain and defers the
//! rest.

use llama::prelude::*;
use llama::view::adapt::{AdaptiveConfig, AdaptiveView};
use llama::view::serve::{AdvisorPool, ServingEngine};
use llama::workloads::nbody::{self, llama_impl};

/// The 13-mapping matrix of `prop_copy_matrix.rs` / `prop_adapt.rs` —
/// every entry a possible starting layout for a served store.
const MATRIX: usize = 13;

fn nth(d: &RecordDim, dims: &ArrayDims, k: usize) -> Box<dyn Mapping> {
    match k {
        0 => Box::new(AoS::aligned(d, dims.clone())),
        1 => Box::new(AoS::packed(d, dims.clone())),
        2 => Box::new(SoA::single_blob(d, dims.clone())),
        3 => Box::new(SoA::multi_blob(d, dims.clone())),
        4 => Box::new(AoSoA::new(d, dims.clone(), 2)),
        5 => Box::new(AoSoA::new(d, dims.clone(), 4)),
        6 => Box::new(AoSoA::new(d, dims.clone(), 8)),
        7 => Box::new(AoSoA::new(d, dims.clone(), 16)),
        8 => Box::new(One::new(d, dims.clone())),
        9 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        )),
        10 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 8),
        )),
        11 => Box::new(Byteswap::new(AoS::packed(d, dims.clone()))),
        12 => Box::new(Heatmap::with_granularity(AoS::packed(d, dims.clone()), 4)),
        _ => unreachable!("matrix has {MATRIX} entries"),
    }
}

struct Move;

impl AdaptiveKernel for Move {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut llama::view::View<M, B>) {
        llama_impl::mv(v);
    }
}

/// All 7 f32 leaves of one state, as stable bit patterns.
fn state_bits(get: impl Fn(usize, usize) -> f32, n: usize) -> Vec<u32> {
    (0..n)
        .flat_map(|lin| (0..nbody::LEAVES).map(move |leaf| get(lin, leaf).to_bits()))
        .collect()
}

/// (1) Generation-swap correctness across the matrix: a serving engine
/// stepping-and-publishing produces generations 1..=S+1 whose contents
/// are bit-identical to the serial `AdaptiveView` reference after
/// 0..=S steps of the same kernel — and guards pinned *before* later
/// steps still read their own generation, verified concurrently while
/// the head keeps churning.
#[test]
fn prop_pinned_readers_observe_whole_generations_bit_identical_to_serial() {
    let d = nbody::particle_dim();
    let n = 96;
    let dims = ArrayDims::linear(n);
    let state = nbody::init_particles(n, 17);
    let steps = 4;
    for start in 0..MATRIX {
        // Serial reference: record the state after 0..=steps steps.
        let mut ref_view = alloc_view(nth(&d, &dims, start));
        llama_impl::load_state(&mut ref_view, &state);
        let mut ref_av = AdaptiveView::new(ref_view, AdaptiveConfig::default());
        let mut expected: Vec<Vec<u32>> = vec![state_bits(|l, f| ref_av.get(l, f), n)];
        let mut ref_names = vec![ref_av.mapping_name()];
        for _ in 0..steps {
            ref_av.step(&mut Move);
            expected.push(state_bits(|l, f| ref_av.get(l, f), n));
            ref_names.push(ref_av.mapping_name());
        }

        // Served run: pin before every step, publish after each.
        let mut v = alloc_view(nth(&d, &dims, start));
        llama_impl::load_state(&mut v, &state);
        let engine = ServingEngine::new(v, AdaptiveConfig::default());
        let mut guards = vec![engine.pin()];
        for _ in 0..steps {
            engine.step_publish(&mut Move);
            guards.push(engine.pin());
        }
        assert_eq!(engine.migrations(), ref_av.migrations(), "start {start}");

        // Concurrent verification: one thread per pinned generation,
        // while the main thread keeps stepping and publishing.
        std::thread::scope(|s| {
            for (i, guard) in guards.iter().enumerate() {
                let expected = &expected[i];
                let ref_name = &ref_names[i];
                s.spawn(move || {
                    assert_eq!(guard.generation(), i as u64 + 1, "start {start}");
                    assert_eq!(
                        &guard.mapping_name(),
                        ref_name,
                        "start {start} generation {i}: layout diverged from serial run"
                    );
                    let got = state_bits(|l, f| guard.get(l, f), n);
                    assert_eq!(
                        &got, expected,
                        "start {start} generation {i}: bytes diverged from serial run"
                    );
                    // Re-read: the pinned snapshot is frozen even while
                    // the head publishes more generations underneath.
                    assert_eq!(state_bits(|l, f| guard.get(l, f), n), got);
                });
            }
            for _ in 0..3 {
                engine.step_publish(&mut Move);
            }
        });
    }
}

/// (2) Warm serving allocates nothing: after one cold round has
/// populated the pool's size classes, a full pin/step/publish/unpin
/// round — including the migration the engine performs — draws every
/// blob from the free lists (`PoolStats::misses` unchanged), and each
/// retired generation's blobs come back to the pool on last unpin.
#[test]
fn prop_warm_engine_serves_and_migrates_with_zero_fresh_allocations() {
    let d = nbody::particle_dim();
    let n = 128;
    let dims = ArrayDims::linear(n);
    let state = nbody::init_particles(n, 23);
    let pool = BlobPool::new();
    let round = |pool: &BlobPool| {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), pool.clone());
        llama_impl::load_state(&mut v, &state);
        let engine = ServingEngine::with_recycler(v, AdaptiveConfig::default(), pool.clone());
        for _ in 0..4 {
            let guard = engine.pin();
            let _: f32 = guard.get(0, 0);
            engine.step_publish(&mut Move);
            drop(guard);
        }
        assert!(engine.migrations() >= 1, "move sweep must trigger a migration");
        // Dropping the engine retires the head and the last published
        // generation; their pooled blobs return to the free lists.
    };
    round(&pool); // cold: populates every size class
    assert!(pool.stats().misses > 0, "cold round must allocate");
    let before = pool.stats();
    assert_eq!(before.outstanding, 0, "everything returned after the cold round");
    round(&pool); // warm: identical traffic, zero fresh blobs
    let after = pool.stats();
    assert_eq!(
        after.misses, before.misses,
        "warm serving round allocated fresh blobs (publish or migration bypassed the pool)"
    );

    // Last-unpin reclamation, explicitly: two guards on one retired
    // generation; only the second drop releases its blobs.
    let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), pool.clone());
    llama_impl::load_state(&mut v, &state);
    let engine = ServingEngine::with_recycler(v, AdaptiveConfig::default(), pool.clone());
    let a = engine.pin();
    let b = a.clone();
    engine.publish(); // retire generation 1: only the guards hold it now
    let held = pool.stats().outstanding;
    drop(a);
    assert_eq!(pool.stats().outstanding, held, "clone still pins the generation");
    drop(b);
    assert!(pool.stats().outstanding < held, "last unpin must free the generation");
}

/// A read-only sweep over a chosen leaf set — the traffic shape that
/// steers the advisor's hot/cold split.
struct TouchLeaves {
    leaves: Vec<usize>,
    sum: f64,
}

impl AdaptiveKernel for TouchLeaves {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut llama::view::View<M, B>) {
        for lin in 0..v.count() {
            for &leaf in &self.leaves {
                self.sum += v.get::<f32>(lin, leaf) as f64;
            }
        }
    }
}

/// (3) The pool migrates exactly the top-`budget` parked decisions by
/// predicted gain: with three stores parking decisions of distinct
/// finite gains, a budget-1 cycle migrates only the best store, the
/// next cycle the runner-up, and deferred stores are untouched
/// in between.
#[test]
fn prop_advisor_pool_migrates_exactly_the_top_k_by_gain() {
    let d = nbody::particle_dim();
    let n = 64;
    let dims = ArrayDims::linear(n);
    let state = nbody::init_particles(n, 31);
    // One steady step between sampling epochs: after a migration, the
    // first update leaves steady and re-arms the tracer, the second is
    // the sampling epoch that parks a fresh decision.
    let cfg = AdaptiveConfig { steady_steps: 1, ..Default::default() };
    let mut pool = AdvisorPool::<VecAlloc>::new(3);
    for _ in 0..3 {
        let mut v = alloc_view(AoS::aligned(&d, dims.clone()));
        llama_impl::load_state(&mut v, &state);
        pool.add(ServingEngine::new(v, cfg));
    }
    // Round 1: identical single-leaf traffic everywhere. First
    // decisions park with infinite gain; budget 3 drains them all, so
    // every store adopts Split(hot = [0]) and has an advised layout.
    for eng in pool.stores() {
        eng.update(&mut TouchLeaves { leaves: vec![0], sum: 0.0 });
    }
    let r = pool.cycle();
    assert_eq!(r.migrated.len(), 3, "round 1 drains all first decisions");
    assert!(r.deferred.is_empty());
    assert!(r.migrated.iter().all(|e| e.gain.is_infinite()));
    for eng in pool.stores() {
        assert_eq!(eng.migrations(), 1);
        assert!(eng.mapping_name().starts_with("Split("), "{}", eng.mapping_name());
    }

    // Round 2: traffic diverges per store — touching 1, 2 and 3 leaves
    // *outside* the adopted hot set parks decisions whose predicted
    // gains differ (fewer cold bytes per useful byte = higher gain).
    pool.set_budget(1);
    let shapes: [Vec<usize>; 3] = [vec![1], vec![1, 2], vec![1, 2, 3]];
    for (eng, leaves) in pool.stores().iter().zip(&shapes) {
        // Two updates: the post-migration steady step, then the
        // sampling epoch whose counts park the decision.
        for _ in 0..2 {
            eng.update(&mut TouchLeaves { leaves: leaves.clone(), sum: 0.0 });
        }
    }
    let pending: Vec<(usize, f64)> = pool
        .stores()
        .iter()
        .enumerate()
        .filter_map(|(i, e)| e.pending_gain().map(|g| (i, g)))
        .collect();
    assert!(pending.len() >= 2, "at least two stores must park finite decisions");
    assert!(pending.iter().all(|(_, g)| g.is_finite() && *g > 1.0), "{pending:?}");
    let mut ranked = pending.clone();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));

    let before: Vec<usize> = pool.stores().iter().map(|e| e.migrations()).collect();
    let r = pool.cycle();
    // Exactly the single top-gain store migrated...
    assert_eq!(r.migrated.len(), 1, "budget 1 migrates exactly one store");
    assert_eq!(r.migrated[0].store, ranked[0].0);
    assert_eq!(r.migrated[0].gain, ranked[0].1);
    assert_eq!(r.deferred.len(), ranked.len() - 1);
    assert!(r.deferred.iter().all(|e| e.gain <= r.migrated[0].gain));
    // ...and only it: deferred stores' migration counters are frozen.
    for (i, eng) in pool.stores().iter().enumerate() {
        let expect = before[i] + usize::from(i == ranked[0].0);
        assert_eq!(eng.migrations(), expect, "store {i}");
    }
    // The next cycle drains the runner-up (its park survives).
    let r = pool.cycle();
    assert_eq!(r.migrated.len(), 1);
    assert_eq!(r.migrated[0].store, ranked[1].0);
    // Round 1 migrated the same AoS -> Split(hot=[0]) pair in all
    // three stores: the fleet-shared cache compiled it once and
    // replayed it for the other two.
    assert!(pool.program_cache().hits() >= 2, "shared cache never reused a program");
}
