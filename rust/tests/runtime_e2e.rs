//! Integration tests over the PJRT runtime: load the AOT artifacts,
//! execute them, and check numerics against the Rust kernels — the
//! whole three-layer stack in one test binary.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built, so `cargo test` works before `make artifacts`; `make test`
//! always builds artifacts first and therefore always exercises them.

use llama::coordinator::bench::Opts;
use llama::coordinator::fig6_xla;
use llama::runtime::{Manifest, Runtime};

fn have_artifacts() -> bool {
    // Needs both the built artifacts and the compiled-in PJRT runtime
    // (`--features xla`); otherwise every test here skips cleanly.
    llama::runtime::available() && Manifest::load("artifacts").is_ok()
}

#[test]
fn manifest_lists_all_seven_variants() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    for name in [
        "nbody_update_soa",
        "nbody_update_aos",
        "nbody_update_soa_notile",
        "nbody_move_soa",
        "nbody_move_aos",
        "nbody_step_soa",
        "nbody_steps_soa",
    ] {
        let a = m.find(name).expect(name);
        assert!(m.path_of(a).exists());
        assert!(a.n > 0 && a.inputs > 0 && a.outputs > 0);
    }
}

#[test]
fn update_soa_matches_rust_kernel() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rel = fig6_xla::verify_against_rust(&Opts::default()).unwrap();
    assert!(rel < 1e-4, "XLA vs Rust rel err {rel}");
}

#[test]
fn aos_and_soa_artifacts_agree() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let n = rt.manifest().find("nbody_update_soa").unwrap().n;
    let (soa_in, _) = fig6_xla::soa_inputs(n, 31);
    let refs: Vec<&[f32]> = soa_in.iter().map(|v| v.as_slice()).collect();
    let soa_out = rt.load("nbody_update_soa").unwrap().run_f32(&refs).unwrap();

    let aos_in = fig6_xla::aos_input(n, 31);
    let aos_out = rt.load("nbody_update_aos").unwrap().run_f32(&[&aos_in]).unwrap();

    // AoS output column 3+d == SoA output d.
    for d in 0..3 {
        for i in 0..n {
            let a = aos_out[0][i * 7 + 3 + d];
            let s = soa_out[d][i];
            let rel = (a - s).abs() / a.abs().max(s.abs()).max(1e-12);
            assert!(rel < 1e-4, "i={i} d={d}: aos {a} vs soa {s}");
        }
    }
}

#[test]
fn step_executable_advances_state() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let exe = rt.load("nbody_step_soa").unwrap();
    let n = exe.meta().n;
    let (inputs, state0) = fig6_xla::soa_inputs(n, 77);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let out = exe.run_f32(&refs).unwrap();
    assert_eq!(out.len(), 8); // 7 state arrays + energy
    let energy = out[7][0];
    assert!(energy.is_finite() && energy > 0.0);
    // Mass is untouched, positions moved.
    assert_eq!(out[6], inputs[6]);
    assert_ne!(out[0], inputs[0]);
    // Position change equals vel_new * dt.
    for i in 0..n {
        let expect = state0.pos[0][i] + out[3][i] * 1e-4;
        let got = out[0][i];
        assert!((expect - got).abs() < 1e-5, "i={i}: {expect} vs {got}");
    }
}

#[test]
fn scan_executable_equals_repeated_steps() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let n = rt.manifest().find("nbody_steps_soa").unwrap().n;
    let (mut state, _) = fig6_xla::soa_inputs(n, 55);

    // 10 applications of the single-step artifact (drop the energy).
    {
        let exe = rt.load("nbody_step_soa").unwrap();
        for _ in 0..10 {
            let refs: Vec<&[f32]> = state.iter().map(|v| v.as_slice()).collect();
            let mut out = exe.run_f32(&refs).unwrap();
            out.pop();
            state = out;
        }
    }
    // One application of the 10-step scan artifact.
    let (orig, _) = fig6_xla::soa_inputs(n, 55);
    let refs: Vec<&[f32]> = orig.iter().map(|v| v.as_slice()).collect();
    let scanned = rt.load("nbody_steps_soa").unwrap().run_f32(&refs).unwrap();

    for (a, b) in scanned.iter().zip(&state) {
        for (x, y) in a.iter().zip(b) {
            let rel = (x - y).abs() / x.abs().max(y.abs()).max(1e-9);
            assert!(rel < 1e-4, "scan vs loop: {x} vs {y}");
        }
    }
}

#[test]
fn wrong_input_arity_is_reported() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let exe = rt.load("nbody_update_soa").unwrap();
    let short: Vec<&[f32]> = vec![];
    let err = exe.run_f32(&short).unwrap_err().to_string();
    assert!(err.contains("expects"), "{err}");
    // Wrong element count in one input.
    let bad = vec![0.0f32; 3];
    let inputs: Vec<&[f32]> = (0..7).map(|_| bad.as_slice()).collect();
    let err = exe.run_f32(&inputs).unwrap_err().to_string();
    assert!(err.contains("expected"), "{err}");
}
