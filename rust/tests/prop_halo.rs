//! Distributed differential acceptance (EXPERIMENTS.md §Wire
//! distributed): an lbm lattice decomposed into x-slabs across N real
//! worker **processes** — exchanging one-plane-deep boundary manifests
//! over localhost TCP each step — reassembles bit-identical to the
//! single-process `step` kernel after K steps, obstacles included.
//! Decomposition and transport may change scheduling; they must never
//! change arithmetic. Wire phase 3 adds the split-phase overlapped
//! schedule (boundary planes first, interior swept while ghosts move):
//! a different order of operations over the same arithmetic, so it too
//! must reassemble bit-identical — blocking and overlapped are
//! differential twins of one oracle.

use std::path::Path;

use llama::coordinator::halo::run_distributed;
use llama::prelude::*;
use llama::workloads::lbm::halo::{run_in_process, run_in_process_overlapped};
use llama::workloads::lbm::step::{init, step};
use llama::workloads::lbm::{cell_dim, Geometry};

/// `steps` ping-pong calls of the undecomposed kernel: the oracle both
/// the in-process and the multi-process decompositions must match.
fn global_oracle(geo: &Geometry, steps: usize) -> View<DynMapping, Vec<u8>> {
    let d = cell_dim();
    let mut a = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    let mut b = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut a, geo);
    init(&mut b, geo);
    for _ in 0..steps {
        step(&a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// The tentpole acceptance test: N spawned `llama halo-worker`
/// processes, boundary planes over real sockets, K steps — the
/// reassembled lattice's bytes equal the oracle's exactly, for both a
/// 2-ring and a 3-ring, around a sphere obstacle, in **both** the
/// blocking and the split-phase overlapped schedule.
#[test]
fn distributed_halo_is_bit_identical_to_the_single_process_kernel() {
    let binary = Path::new(env!("CARGO_BIN_EXE_llama"));
    let geo = Geometry::channel_with_sphere(10, 6, 6, 7);
    let steps = 3;
    let oracle = global_oracle(&geo, steps);
    // The in-process twins first: if these diverge, the bug is in the
    // decomposition or the split-phase schedule, not the transport.
    let twin = run_in_process(&geo, 3, steps).unwrap();
    assert_eq!(twin.blobs(), oracle.blobs(), "in-process decomposition diverged");
    let twin_ov = run_in_process_overlapped(&geo, 3, steps).unwrap();
    assert_eq!(twin_ov.blobs(), oracle.blobs(), "in-process overlapped schedule diverged");
    for workers in [2usize, 3] {
        for overlap in [false, true] {
            let got = run_distributed(&geo, steps, workers, Some(binary), overlap).unwrap();
            assert_eq!(
                got.blobs(),
                oracle.blobs(),
                "{workers}-process halo exchange (overlap={overlap}) diverged from the \
                 single-process kernel"
            );
        }
    }
}

/// The overlapped-vs-blocking differential oracle at a second
/// geometry: thin slabs (5 planes over 3 workers, so one worker owns a
/// single plane and `step_interior` degenerates to nothing — the
/// schedule is all boundary work) — the regime where the split-phase
/// bookkeeping has the least slack.
#[test]
fn overlapped_schedule_survives_thin_slabs() {
    let binary = Path::new(env!("CARGO_BIN_EXE_llama"));
    let geo = Geometry::channel_with_sphere(5, 5, 5, 17);
    let steps = 4;
    let oracle = global_oracle(&geo, steps);
    let twin_ov = run_in_process_overlapped(&geo, 3, steps).unwrap();
    assert_eq!(twin_ov.blobs(), oracle.blobs(), "thin-slab overlapped twin diverged");
    let got = run_distributed(&geo, steps, 3, Some(binary), true).unwrap();
    assert_eq!(got.blobs(), oracle.blobs(), "thin-slab distributed overlap diverged");
}

/// Zero steps exercises only distribution and reassembly: scatter the
/// initial lattice to the workers, gather the interiors back, and the
/// bytes must equal the freshly initialized global — in either
/// schedule, since neither ever runs.
#[test]
fn zero_step_distribution_reassembles_the_initial_lattice() {
    let binary = Path::new(env!("CARGO_BIN_EXE_llama"));
    let geo = Geometry::channel_with_sphere(8, 5, 5, 21);
    for overlap in [false, true] {
        let got = run_distributed(&geo, 0, 2, Some(binary), overlap).unwrap();
        assert_eq!(got.blobs(), global_oracle(&geo, 0).blobs(), "overlap={overlap}");
    }
}

/// The `llama halo` demo end to end in both schedules: spawns its
/// workers, verifies the exchange against the oracle, zero exit code,
/// and reports which schedule ran.
#[test]
fn halo_command_verifies_bit_identity() {
    for overlap in [false, true] {
        let mut args = vec!["halo", "--quick", "--iters", "2"];
        if overlap {
            args.push("--overlap");
        }
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_llama"))
            .args(&args)
            .output()
            .expect("run llama halo");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "llama halo {args:?} failed: {stdout}\n{stderr}");
        assert!(stdout.contains("bit-identical to single-process step"), "{stdout}");
        assert!(stdout.contains("worker processes"), "{stdout}");
        let want = if overlap { "overlapped (split-phase)" } else { "blocking ring" };
        assert!(stdout.contains(want), "schedule row missing {want:?}: {stdout}");
    }
}
