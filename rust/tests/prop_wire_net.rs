//! Socket-transport acceptance properties (EXPERIMENTS.md §Wire
//! distributed): the framed reader survives a hostile byte stream —
//! header reads are byte-capped, split writes and byte-at-a-time
//! delivery reassemble, abrupt disconnects surface as errors, and
//! `Ok(None)` means a clean frame boundary and nothing else — and the
//! TCP slab server (`llama wire-serve`) round trips multiplexed
//! `(step, range)`-tagged sends over ONE `PeerLink` from a real
//! client across a real process boundary, out-of-order and
//! interleaved across steps. A deliberately silent peer must surface
//! as a clear timeout error, never a hang.

mod prop_support;

use std::io::{BufReader, Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::time::Duration;

use llama::coordinator::wire_demo::DRIFT_DT;
use llama::coordinator::wire_net::{self, PeerLink, WIRE_IO_TIMEOUT};
use llama::prelude::*;
use llama::workloads::nbody;
use llama::workloads::picframe::frames::drift_view;
use llama::workloads::picframe::attr_dim;
use prop_support::*;

fn sample_frame_bytes() -> (WireMessage, Vec<u8>) {
    let d = nbody::particle_dim();
    let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(13)));
    fill_sentinels(&mut src);
    let msg = serialize(&src).unwrap();
    let mut bytes = Vec::new();
    write_message(&mut bytes, &msg).unwrap();
    (msg, bytes)
}

/// A newline-free hostile stream must be rejected once the byte-capped
/// header read gives up — it must never be buffered without bound in
/// search of a newline.
#[test]
fn newline_free_streams_are_rejected_at_the_header_cap() {
    let hostile = vec![b'A'; 4 * MAX_HEADER_BYTES as usize];
    let err = read_message(&mut Cursor::new(hostile)).unwrap_err();
    let text = err.to_string();
    assert!(text.contains("truncated or longer"), "unexpected error: {text}");

    // Exactly at the cap with no newline: same rejection, no panic.
    let at_cap = vec![b'L'; MAX_HEADER_BYTES as usize];
    assert!(read_message(&mut Cursor::new(at_cap)).is_err());

    // A newline *within* the cap still parses normally.
    let (msg, bytes) = sample_frame_bytes();
    let got = read_message(&mut Cursor::new(bytes)).unwrap().expect("one frame");
    assert_eq!(got, msg);
}

/// `Ok(None)` is reserved for the clean frame boundary: an empty
/// stream and the position after a whole frame. Every truncation —
/// mid-header, mid-manifest, mid-payload — is an error.
#[test]
fn none_means_clean_frame_boundary_and_nothing_else() {
    let (msg, bytes) = sample_frame_bytes();

    // Clean boundaries.
    assert!(read_message(&mut Cursor::new(Vec::new())).unwrap().is_none());
    let mut r = Cursor::new(bytes.clone());
    assert_eq!(read_message(&mut r).unwrap().expect("frame"), msg);
    assert!(read_message(&mut r).unwrap().is_none(), "EOF after a whole frame");

    // A header cut off by EOF before its newline is an error.
    assert!(read_message(&mut Cursor::new(b"LLAMA-WIRE 50".to_vec())).is_err());

    // Truncation at every prefix length: nothing but the two clean
    // boundaries may produce `Ok(None)`, and no prefix may panic.
    for cut in 1..bytes.len() {
        match read_message(&mut Cursor::new(bytes[..cut].to_vec())) {
            Err(_) => {}
            Ok(got) => panic!("truncation at byte {cut}/{} returned {got:?}", bytes.len()),
        }
    }

    // Trailing garbage after a clean frame is an error, not EOF.
    let mut noisy = bytes.clone();
    noisy.extend_from_slice(b"LL");
    let mut r = Cursor::new(noisy);
    assert!(read_message(&mut r).unwrap().is_some());
    assert!(read_message(&mut r).is_err(), "partial next header must not read as EOF");
}

/// A reader that delivers at most one byte per call — the worst
/// fragmentation a socket can legally produce.
struct Trickle<R>(R);

impl<R: Read> Read for Trickle<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = buf.len().min(1);
        self.0.read(&mut buf[..n])
    }
}

/// Byte-at-a-time delivery reassembles every frame bit-identically:
/// framing never assumes a read returns more than one byte.
#[test]
fn byte_at_a_time_delivery_reassembles_whole_frames() {
    let d = nbody::particle_dim();
    let mut stream = Vec::new();
    let mut sent = Vec::new();
    for (k, endian) in
        [WireEndian::native(), WireEndian::native().swapped()].into_iter().enumerate()
    {
        let mut src = alloc_view(AoSoA::new(&d, ArrayDims::linear(21), 4));
        fill_sentinels(&mut src);
        let msg = serialize_range_endian(&src, k, 19 + k, endian).unwrap();
        write_message(&mut stream, &msg).unwrap();
        sent.push(msg);
    }
    let mut r = BufReader::with_capacity(1, Trickle(Cursor::new(stream)));
    for (k, want) in sent.iter().enumerate() {
        let got = read_message(&mut r).unwrap().unwrap_or_else(|| panic!("frame {k}"));
        assert_eq!(&got, want, "frame {k}");
    }
    assert!(read_message(&mut r).unwrap().is_none());
}

/// Real sockets: split writes with flushes in between reassemble into
/// whole frames, and an abrupt peer disconnect mid-manifest or
/// mid-payload surfaces as an error on the reader — never as a clean
/// end of stream.
#[test]
fn split_socket_writes_reassemble_and_disconnects_surface_as_errors() {
    let (msg, bytes) = sample_frame_bytes();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Cut points: inside the header/manifest text and inside the
    // payload (the payload is 13 × 28 B, so len-10 is always in it).
    let cuts = [30usize, bytes.len() - 10];

    let frame = bytes.clone();
    let writer = std::thread::spawn(move || {
        // Connection 1: dribble the whole frame in 7-byte chunks.
        let mut s = TcpStream::connect(addr).unwrap();
        for chunk in frame.chunks(7) {
            s.write_all(chunk).unwrap();
            s.flush().unwrap();
        }
        drop(s);
        // Connections 2..: send a prefix, then disconnect abruptly.
        for cut in cuts {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&frame[..cut]).unwrap();
            drop(s);
        }
    });

    let (s, _) = listener.accept().unwrap();
    let mut r = BufReader::new(s);
    assert_eq!(read_message(&mut r).unwrap().expect("dribbled frame"), msg);
    assert!(read_message(&mut r).unwrap().is_none(), "clean close after the frame");

    for cut in cuts {
        let (s, _) = listener.accept().unwrap();
        let mut r = BufReader::new(s);
        assert!(
            read_message(&mut r).is_err(),
            "disconnect after {cut} bytes must error, not end cleanly"
        );
    }
    writer.join().unwrap();
}

/// The slab server across a real process boundary: spawn `llama
/// wire-serve`, drive one single-stream exchange and one multiplexed
/// `PeerLink` session from this process, and check everything lands
/// bit-identical to the locally computed drifted oracle. The link
/// carries two steps' shards interleaved — all queued before a single
/// reply is claimed, then claimed in reverse order — so the replies
/// arrive out of order relative to every receiver and the dispatcher
/// must park them.
#[test]
fn wire_serve_process_round_trips_multiplexed_slabs() {
    const SHARDS: usize = 3;
    let binary = Path::new(env!("CARGO_BIN_EXE_llama"));
    let (mut child, addr) = wire_net::spawn_server(binary, 2).unwrap();

    let d = attr_dim();
    let dims = ArrayDims::linear(96);
    let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
    fill_sentinels(&mut src);
    let mut expected = alloc_view(SoA::multi_blob(&d, dims.clone()));
    copy(&src, &mut expected);
    drift_view(&mut expected, dims.count(), DRIFT_DT);

    // Single stream, foreign byte order: the whole-frame path.
    {
        let s = TcpStream::connect(addr.as_str()).expect("connect to wire-serve");
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut w = s;
        let request = serialize_endian(&src, WireEndian::native().swapped()).unwrap();
        write_message(&mut w, &request).unwrap();
        let reply = read_message(&mut r).unwrap().expect("frame reply");
        assert_eq!(reply.manifest.endian, request.manifest.endian, "reply keeps the byte order");
        let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
        deserialize_into(&reply, &mut got).unwrap();
        assert!(views_equal(&got, &expected), "single-stream slab diverged from the oracle");
    }

    // Multiplexed: every sub-range a `(step, range)`-tagged frame on
    // ONE persistent link; two steps interleaved, claimed in reverse.
    let link = PeerLink::connect(&addr, WIRE_IO_TIMEOUT).unwrap();
    let mut tags = Vec::new();
    for step in [2usize, 5] {
        let endian =
            if step == 2 { WireEndian::native().swapped() } else { WireEndian::native() };
        let mut msgs = serialize_sharded(&src, endian, SHARDS).unwrap();
        assert_eq!(msgs.len(), SHARDS);
        for m in &mut msgs {
            m.manifest.step = Some(step);
            tags.push((step, m.manifest.range.unwrap()));
        }
        for m in msgs {
            link.send(m).unwrap();
        }
    }
    let mut by_step: Vec<Vec<WireMessage>> = vec![Vec::new(), Vec::new()];
    for &(step, range) in tags.iter().rev() {
        let reply = link.recv_tagged(step, range).unwrap();
        assert_eq!(reply.manifest.step, Some(step), "reply keeps the step tag");
        assert_eq!(reply.manifest.range, Some(range), "reply keeps the range tag");
        by_step[usize::from(step == 5)].push(reply);
    }
    drop(link);
    for replies in by_step {
        let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
        deserialize_sharded_into(&replies, &mut got).unwrap();
        assert!(views_equal(&got, &expected), "multiplexed slabs diverged from the oracle");
    }

    let status = child.wait().unwrap();
    assert!(status.success(), "wire-serve exited with {status}");
}

/// A peer that accepts the connection and then never sends a byte:
/// the transport deadline must turn the infinite wait into an error
/// naming the timeout — the silent-peer regression the phase-2
/// transport (no read timeouts) would hang on.
#[test]
fn silent_peer_surfaces_as_a_timeout_error_not_a_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let link = PeerLink::connect(&addr, Duration::from_millis(200)).unwrap();
    let (silent, _) = listener.accept().unwrap();
    let err = link.recv_step(0).unwrap_err().to_string();
    assert!(err.contains("timed out"), "expected a timeout error, got: {err}");
    // The link stays failed: later receives report the same cause
    // instead of waiting again.
    let err2 = link.recv_tagged(3, (0, 8)).unwrap_err().to_string();
    assert!(err2.contains("timed out"), "{err2}");
    drop(silent);
    drop(link);
}

/// The `llama wire-connect` demo end to end: spawns its own private
/// server, runs the staged, pipelined, and multiplexed exchanges,
/// verifies every round trip, zero exit code.
#[test]
fn wire_connect_command_verifies_its_exchange() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_llama"))
        .args(["wire-connect", "--quick", "--n", "64", "--iters", "2"])
        .output()
        .expect("run llama wire-connect");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "llama wire-connect failed: {stdout}\n{stderr}");
    assert!(stdout.contains("TCP socket exchange"), "{stdout}");
    assert!(stdout.contains("multiplexed"), "{stdout}");
    assert!(stdout.contains("pipelined"), "{stdout}");
    assert!(stdout.contains("verified"), "{stdout}");
}
