//! Property harness for `blob::pool` (EXPERIMENTS.md §Alloc): (1)
//! size-class and alignment-tier invariants over random request sizes;
//! (2) recycle-reuse — a returned blob's block is handed back to the
//! next same-class request, re-zeroed over the exposed range; (3) no
//! aliasing among outstanding blobs — concurrently live blobs occupy
//! disjoint address ranges and never clobber each other; (4) views
//! allocated through the pool are indistinguishable from `Vec<u8>`
//! views under the sentinel filler, and a warm pool serves whole-view
//! reallocation with zero fresh blocks.

mod prop_support;

use llama::blob::pool::{class_align, class_of, LARGE_PAGE_BYTES, MIN_CLASS_BYTES};
use llama::blob::PooledBytes;
use llama::prelude::*;
use llama::workloads::rng::SplitMix64;
use prop_support::*;

/// (1) Size classes are powers of two at or above the request (and the
/// 64-byte floor); the alignment tier follows the class; the exposed
/// length is exactly the request; the start pointer honors the tier.
#[test]
fn prop_class_and_alignment_invariants() {
    let pool = BlobPool::new();
    let mut rng = SplitMix64::new(0x9001);
    for case in 0..cases() {
        let size = match rng.below(3) {
            0 => 1 + rng.below(300),
            1 => 1 + rng.below(1 << 14),
            _ => (1 << 20) + rng.below(1 << 20),
        };
        let class = class_of(size);
        assert!(class.is_power_of_two() && class >= size && class >= MIN_CLASS_BYTES);
        assert!(class < 2 * size.max(MIN_CLASS_BYTES), "class {class} overshoots {size}");
        let align = class_align(class);
        assert!(align == 64 || align == 4096 || align == LARGE_PAGE_BYTES);
        let b = pool.allocate(size);
        assert_eq!(b.as_bytes().len(), size, "case {case}");
        assert_eq!(b.capacity(), class, "case {case}");
        assert_eq!(b.align(), align, "case {case}");
        assert_eq!(b.as_bytes().as_ptr() as usize % align, 0, "case {case}");
        assert!(b.as_bytes().iter().all(|&x| x == 0), "case {case}: not zeroed");
        drop(b);
        // Keep the raised-case CI sweep's footprint flat: park nothing.
        pool.trim();
    }
    // Everything allocated above was dropped at the end of its case.
    assert_eq!(pool.stats().outstanding, 0);
}

/// (2) Recycle-reuse: dropping a blob parks its block; the next
/// request of the same class pops exactly that block (LIFO), with the
/// exposed range re-zeroed no matter what the previous user wrote.
#[test]
fn prop_recycle_hands_capacity_back_rezeroed() {
    let mut rng = SplitMix64::new(0x9002);
    for case in 0..cases() {
        let pool = BlobPool::new();
        let size = 1 + rng.below(4096);
        let addr = {
            let mut a = pool.allocate(size);
            let fill = (case as u8) | 1;
            a.as_bytes_mut().fill(fill);
            a.as_bytes().as_ptr() as usize
        };
        // Any size in the same class reuses the block.
        let class = class_of(size);
        let size2 = class / 2 + 1 + rng.below(class / 2);
        assert_eq!(class_of(size2), class, "case {case}: sizes must share a class");
        let b = pool.allocate(size2);
        assert_eq!(b.as_bytes().as_ptr() as usize, addr, "case {case}: block not recycled");
        assert!(b.as_bytes().iter().all(|&x| x == 0), "case {case}: stale bytes leaked");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.outstanding), (1, 1, 1), "case {case}");
        assert_eq!(s.recycled_bytes, size2, "case {case}");
    }
}

/// (3) No aliasing among outstanding blobs: address ranges of live
/// blobs are pairwise disjoint, and writes through one never show up
/// in another — even while other blobs of the same class churn.
#[test]
fn prop_outstanding_blobs_are_disjoint() {
    let mut rng = SplitMix64::new(0x9003);
    for case in 0..cases() / 2 {
        let pool = BlobPool::new();
        let mut live: Vec<(PooledBytes, u8)> = Vec::new();
        for step in 0..40 {
            if live.is_empty() || rng.below(3) > 0 {
                let size = 1 + rng.below(2048);
                let mut b = pool.allocate(size);
                let tag = (step as u8).wrapping_mul(37) | 1;
                b.as_bytes_mut().fill(tag);
                live.push((b, tag));
            } else {
                live.swap_remove(rng.below(live.len()));
            }
        }
        assert_eq!(pool.stats().outstanding, live.len(), "case {case}");
        // Pairwise-disjoint *capacity* ranges (the whole backing block,
        // not just the exposed prefix).
        let mut ranges: Vec<(usize, usize)> = live
            .iter()
            .map(|(b, _)| {
                let a = b.as_bytes().as_ptr() as usize;
                (a, a + b.capacity())
            })
            .collect();
        ranges.sort_unstable();
        for w in ranges.windows(2) {
            assert!(w[0].1 <= w[1].0, "case {case}: blocks overlap: {w:?}");
        }
        for (i, (b, tag)) in live.iter().enumerate() {
            assert!(
                b.as_bytes().iter().all(|&x| x == *tag),
                "case {case}: blob {i} clobbered"
            );
        }
    }
}

/// (4) Views over pooled blobs are bit-identical to `Vec<u8>` views
/// under the sentinel filler across random mappings, and re-allocating
/// the same view shape from a warm pool performs zero fresh
/// allocations.
#[test]
fn prop_pooled_views_match_vec_views_and_rewarm() {
    let mut rng = SplitMix64::new(0x9004);
    for seed in 0..cases() / 2 {
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let pool = BlobPool::new();
        {
            let mut pooled = alloc_view_with(gen_mapping_at(seed, &dim, &dims), pool.clone());
            let mut plain = alloc_view(gen_mapping_at(seed, &dim, &dims));
            fill_sentinels(&mut pooled);
            fill_sentinels(&mut plain);
            for (p, v) in pooled.blobs().iter().zip(plain.blobs()) {
                assert_eq!(p.as_bytes(), v.as_slice(), "seed {seed}: pooled != vec");
            }
        }
        let misses = pool.stats().misses;
        let again = alloc_view_with(gen_mapping_at(seed, &dim, &dims), pool.clone());
        assert_eq!(pool.stats().misses, misses, "seed {seed}: warm realloc missed");
        // Zeroed like a fresh view.
        assert!(
            again.blobs().iter().all(|b| b.as_bytes().iter().all(|&x| x == 0)),
            "seed {seed}: recycled view not zeroed"
        );
    }

    /// The same mapping twice (gen_mapping advances the rng, so derive
    /// a fresh deterministic generator per use).
    fn gen_mapping_at(
        seed: u64,
        dim: &RecordDim,
        dims: &ArrayDims,
    ) -> Box<dyn Mapping> {
        let mut rng = SplitMix64::new(seed ^ 0xB10B);
        gen_mapping(&mut rng, dim, dims)
    }
}
