//! Property tests over randomly generated record dims × array dims ×
//! mappings (DESIGN.md §8): non-overlap, containment, round-trip — the
//! invariants that make every storage mapping a valid layout and that
//! the parallel engines rely on for soundness.

mod prop_support;

use std::collections::HashMap;

use llama::prelude::*;
use llama::workloads::rng::SplitMix64;
use prop_support::*;

/// (a) + (b): every (leaf, lin) maps to a byte range inside its blob,
/// and distinct (leaf, lin) pairs map to disjoint ranges.
#[test]
fn prop_non_overlap_and_containment() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        let info = m.info().clone();

        let mut used: HashMap<usize, Vec<(usize, usize, usize, usize)>> = HashMap::new();
        for lin in 0..dims.count() {
            let slot = m.slot_of_lin(lin);
            for leaf in 0..info.leaf_count() {
                let size = info.fields[leaf].size();
                let (nr, off) = m.blob_nr_and_offset(leaf, slot);
                assert!(nr < m.blob_count(), "seed {seed}: blob out of range");
                assert!(
                    off + size <= m.blob_size(nr),
                    "seed {seed}: {} leaf {leaf} lin {lin} escapes blob {nr}",
                    m.mapping_name()
                );
                used.entry(nr).or_default().push((off, off + size, leaf, lin));
            }
        }
        for (nr, mut ranges) in used {
            ranges.sort();
            for w in ranges.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "seed {seed}: overlap in blob {nr} of {}: {:?} vs {:?}",
                    m.mapping_name(),
                    w[0],
                    w[1]
                );
            }
        }
    }
}

/// (c) round-trip: sentinel bytes written to every (leaf, lin) read
/// back unchanged everywhere — no cross-talk through any mapping.
#[test]
fn prop_sentinel_roundtrip() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0x5EED);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        let name = m.mapping_name();
        let info = m.info().clone();
        let mut view = alloc_view(m);
        fill_sentinels(&mut view);
        for lin in 0..view.count() {
            for leaf in 0..info.leaf_count() {
                let size = info.fields[leaf].size();
                let slot = view.mapping().slot_of_lin(lin);
                let (nr, off) = view.mapping().blob_nr_and_offset(leaf, slot);
                let got = &view.blobs()[nr].as_bytes()[off..off + size];
                let expect = sentinel_bytes(leaf, lin, size);
                assert_eq!(got, expect.as_slice(), "seed {seed}: {name} leaf {leaf} lin {lin}");
            }
        }
    }
}

/// Total blob bytes are at least the payload (packed size × slot count;
/// aligned layouts may pad) and bounded by a sane factor.
#[test]
fn prop_blob_sizes_bound_payload() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0xB10B);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        let total: usize = (0..m.blob_count()).map(|b| m.blob_size(b)).sum();
        let payload = dim.packed_size() * dims.count();
        assert!(
            total >= payload,
            "seed {seed}: {} stores {total} < payload {payload}",
            m.mapping_name()
        );
        // Aligned/tail/Morton padding can inflate storage, but by less
        // than aligned-record-size per slot-count x 8 (Morton rounds
        // each extent up to a power of two: < 2^rank <= 8 for rank<=3).
        let info = m.info().clone();
        let bound = info.aligned_size.max(info.packed_size) * dims.count() * 8 + 64;
        assert!(
            total <= bound,
            "seed {seed}: {} stores {total} > bound {bound}",
            m.mapping_name()
        );
    }
}

/// slot_of_nd and slot_of_lin agree through the canonical row-major
/// delinearization.
#[test]
fn prop_nd_lin_consistency() {
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0x11D);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        for lin in 0..dims.count() {
            let idx = dims.delinearize_row_major(lin);
            assert_eq!(
                m.slot_of_nd(&idx),
                m.slot_of_lin(lin),
                "seed {seed}: {} lin {lin}",
                m.mapping_name()
            );
        }
    }
}

/// Every mapping's compiled `LayoutPlan` resolves exactly like
/// `blob_nr_and_offset` for all leaves × linear indices. Generic plans
/// must fall back to the mapping (trivially equal); closed-form plans
/// (affine/piecewise) must agree everywhere — including AoSoA lane
/// boundaries (tail blocks), Split compositions, and wrappers.
#[test]
fn prop_plan_resolves_like_mapping() {
    fn check(m: &dyn Mapping, label: &str) {
        let plan = m.plan();
        assert_eq!(plan.count(), m.dims().count(), "{label}: plan count");
        assert_eq!(
            plan.native(),
            m.is_native_representation(),
            "{label}: plan native flag"
        );
        // The derived trait accessors must agree with the plan.
        assert_eq!(m.aosoa_lanes(), plan.chunk_lanes(), "{label}: lanes");
        for lin in 0..m.dims().count() {
            let slot = m.slot_of_lin(lin);
            for leaf in 0..m.info().leaf_count() {
                let want = m.blob_nr_and_offset(leaf, slot);
                if let Some(got) = plan.resolve(leaf, lin) {
                    assert_eq!(got, want, "{label}: leaf {leaf} lin {lin} (closed form)");
                }
                assert_eq!(
                    plan.resolve_with(m, leaf, lin),
                    want,
                    "{label}: leaf {leaf} lin {lin} (resolve_with)"
                );
            }
        }
    }

    // Random record dims × array dims × mappings.
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0x91A5);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let m = gen_mapping(&mut rng, &dim, &dims);
        check(m.as_ref(), &format!("seed {seed}: {}", m.mapping_name()));
    }

    // Explicit acceptance matrix on multi-dimensional extents whose
    // count (3*5*2 = 30) is not a multiple of most lane counts.
    let d = gen_record_dim(&mut SplitMix64::new(4242));
    let dims = ArrayDims::from([3, 5, 2]);
    let mut cases: Vec<(String, Box<dyn Mapping>)> = vec![
        ("AoS aligned".into(), Box::new(AoS::aligned(&d, dims.clone()))),
        ("AoS packed".into(), Box::new(AoS::packed(&d, dims.clone()))),
        ("SoA MB".into(), Box::new(SoA::multi_blob(&d, dims.clone()))),
        ("SoA SB".into(), Box::new(SoA::single_blob(&d, dims.clone()))),
        ("One".into(), Box::new(One::new(&d, dims.clone()))),
        (
            "Byteswap(AoS)".into(),
            Box::new(Byteswap::new(AoS::packed(&d, dims.clone()))),
        ),
        (
            "Trace(AoSoA4)".into(),
            Box::new(Trace::new(AoSoA::new(&d, dims.clone(), 4))),
        ),
        (
            "Heatmap(SoA)".into(),
            Box::new(Heatmap::new(SoA::multi_blob(&d, dims.clone()))),
        ),
    ];
    for lanes in [2usize, 4, 8, 16] {
        cases.push((format!("AoSoA{lanes}"), Box::new(AoSoA::new(&d, dims.clone(), lanes))));
    }
    if d.fields.len() >= 2 {
        let sel = RecordCoord::new(vec![0]);
        cases.push((
            "Split(SoA|AoS)".into(),
            Box::new(Split::new(
                &d,
                dims.clone(),
                sel.clone(),
                |sd, ad| SoA::multi_blob(sd, ad),
                |sd, ad| AoS::aligned(sd, ad),
            )),
        ));
        cases.push((
            "Split(AoSoA4|SoA)".into(),
            Box::new(Split::new(
                &d,
                dims.clone(),
                sel.clone(),
                |sd, ad| AoSoA::new(sd, ad, 4),
                |sd, ad| SoA::multi_blob(sd, ad),
            )),
        ));
        cases.push((
            "Split(AoS|AoSoA8)".into(),
            Box::new(Split::new(
                &d,
                dims.clone(),
                sel,
                |sd, ad| AoS::packed(sd, ad),
                |sd, ad| AoSoA::new(sd, ad, 8),
            )),
        ));
    }
    for (label, m) in &cases {
        check(m.as_ref(), label);
    }
}

/// Instrumentation wrappers (Trace/Heatmap/Byteswap) forward the layout
/// unchanged.
#[test]
fn prop_wrappers_preserve_layout() {
    for seed in 0..cases() / 2 {
        let mut rng = SplitMix64::new(seed ^ 0x77AE);
        let dim = gen_record_dim(&mut rng);
        let dims = gen_dims(&mut rng);
        let inner = AoSoA::new(&dim, dims.clone(), 1 + rng.below(8));
        let traced = Trace::new(inner.clone());
        let heat = Heatmap::with_granularity(inner.clone(), 1 + rng.below(64));
        let swapped = Byteswap::new(inner.clone());
        for lin in 0..dims.count() {
            let slot = inner.slot_of_lin(lin);
            for leaf in 0..inner.info().leaf_count() {
                let want = inner.blob_nr_and_offset(leaf, slot);
                assert_eq!(traced.blob_nr_and_offset(leaf, slot), want);
                assert_eq!(heat.blob_nr_and_offset(leaf, slot), want);
                assert_eq!(swapped.blob_nr_and_offset(leaf, slot), want);
            }
        }
        assert_eq!(
            traced.report().iter().map(|(_, c)| *c).sum::<u64>() as usize,
            dims.count() * inner.info().leaf_count()
        );
    }
}
