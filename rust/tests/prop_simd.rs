//! SIMD bit-identity suite (EXPERIMENTS.md §SIMD): every `*_simd*`
//! entry point must produce **bit-identical** results to its scalar
//! twin, on every [`SimdPath`] the host offers (always at least
//! `Scalar`, so the suite is meaningful with or without `--features
//! simd`), across the full 13-mapping layout matrix including
//! tail-block extents, generic-plan fallbacks, and the packed-AoS
//! gather path. The `One` mapping is excluded from the n-body kernel
//! identity checks only: it aliases every record onto the same bytes,
//! so the scalar kernel's sequential read-after-write dependence is
//! semantically different from any batched schedule — batching it is
//! not a supported operation, and the executor runs it through the
//! scalar fallback anyway.

mod prop_support;

use llama::copy::program::{execute_parallel_with, shard_programs};
use llama::prelude::*;
use llama::view::simd::available_paths;
use llama::workloads::lbm;
use llama::workloads::nbody;
use llama::workloads::nbody::llama_impl as nb;
use prop_support::*;

/// Explicit layout matrix (same as `prop_copy_matrix`); index 8 is the
/// aliasing `One` mapping.
const MATRIX: usize = 13;
const ONE_IDX: usize = 8;

fn nth(d: &RecordDim, dims: &ArrayDims, k: usize) -> Box<dyn Mapping> {
    match k {
        0 => Box::new(AoS::aligned(d, dims.clone())),
        1 => Box::new(AoS::packed(d, dims.clone())),
        2 => Box::new(SoA::single_blob(d, dims.clone())),
        3 => Box::new(SoA::multi_blob(d, dims.clone())),
        4 => Box::new(AoSoA::new(d, dims.clone(), 2)),
        5 => Box::new(AoSoA::new(d, dims.clone(), 4)),
        6 => Box::new(AoSoA::new(d, dims.clone(), 8)),
        7 => Box::new(AoSoA::new(d, dims.clone(), 16)),
        8 => Box::new(One::new(d, dims.clone())),
        9 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        )),
        10 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 8),
        )),
        11 => Box::new(Byteswap::new(AoS::packed(d, dims.clone()))),
        12 => Box::new(Heatmap::with_granularity(AoS::packed(d, dims.clone()), 4)),
        _ => unreachable!("matrix has {MATRIX} entries"),
    }
}

/// Every mapping in the matrix (minus the aliasing `One`), every
/// available path, serial and sharded: two n-body `update`+`mv` rounds
/// through the lane-batch kernels reproduce the scalar state bit for
/// bit. 97 records: prime, so every lane width (4 and 8) and every
/// AoSoA block size sees a tail. Mappings 11/12 (Byteswap, Heatmap)
/// compile to generic plans and exercise the scalar accessor fallback
/// under a vector `path`.
#[test]
fn prop_nbody_simd_bit_identical_across_matrix() {
    let d = nbody::particle_dim();
    for dims in [ArrayDims::linear(97), ArrayDims::from([5, 7])] {
        let n = dims.count();
        let state = nbody::init_particles(n, 41);
        // Scalar reference, once per extent.
        let mut reference = alloc_view(AoS::aligned(&d, dims.clone()));
        nb::load_state(&mut reference, &state);
        for _ in 0..2 {
            nb::update(&mut reference);
            nb::mv(&mut reference);
        }
        let expect = nb::store_state(&reference);
        for k in (0..MATRIX).filter(|&k| k != ONE_IDX) {
            for path in available_paths() {
                for threads in [1usize, 3] {
                    let mut v = alloc_view(nth(&d, &dims, k));
                    nb::load_state(&mut v, &state);
                    for _ in 0..2 {
                        nb::update_simd_parallel_with(&mut v, threads, path);
                        nb::mv_simd_parallel_with(&mut v, threads, path);
                    }
                    assert_eq!(
                        nb::store_state(&v),
                        expect,
                        "mapping {k} ({}) path {path:?} threads {threads} ({dims:?})",
                        v.mapping().mapping_name()
                    );
                }
            }
        }
    }
}

/// D3Q19 LBM: the lane-batched step reproduces the scalar step bit for
/// bit on every available path — including obstacle-carrying batches,
/// z-tails (nz = 6 vs AVX2's 4-lane blocks), and a generic-plan
/// mapping (Heatmap) that must take the scalar accessor fallback under
/// a vector `path`.
#[test]
fn prop_lbm_simd_bit_identical() {
    fn check<M: Mapping>(make: impl Fn() -> M, geo: &lbm::Geometry, name: &str) {
        let mut a = alloc_view(make());
        let mut b = alloc_view(make());
        lbm::step::init(&mut a, geo);
        lbm::step::init(&mut b, geo);
        for _ in 0..3 {
            lbm::step::step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        for path in available_paths() {
            for threads in [1usize, 2] {
                let mut sa = alloc_view(make());
                let mut sb = alloc_view(make());
                lbm::step::init(&mut sa, geo);
                lbm::step::init(&mut sb, geo);
                for _ in 0..3 {
                    lbm::step::step_simd_parallel_with(&sa, &mut sb, threads, path);
                    std::mem::swap(&mut sa, &mut sb);
                }
                assert_eq!(
                    a.blobs(),
                    sa.blobs(),
                    "{name}: path {path:?} threads {threads} differs from scalar"
                );
            }
        }
    }
    let geo = lbm::Geometry::channel_with_sphere(5, 4, 6, 7);
    let d = lbm::cell_dim();
    check(|| AoS::packed(&d, geo.dims.clone()), &geo, "AoS packed");
    check(|| SoA::multi_blob(&d, geo.dims.clone()), &geo, "SoA MB");
    check(|| AoSoA::new(&d, geo.dims.clone(), 8), &geo, "AoSoA-8");
    check(
        || Heatmap::with_granularity(AoS::packed(&d, geo.dims.clone()), 4),
        &geo,
        "Heatmap(AoS packed)",
    );
}

/// `CopyProgram` execution with a pinned path is bit-identical to the
/// naive oracle on **every** pair of the matrix that compiles at least
/// one `StridedRun` — the ops the SIMD gather kernels execute — both
/// through the serial slice site and the raw-pointer parallel site.
#[test]
fn prop_strided_run_simd_matches_oracle_across_matrix() {
    let d = nbody::particle_dim();
    for dims in [ArrayDims::linear(97), ArrayDims::from([5, 7])] {
        for i in 0..MATRIX {
            let mut src = alloc_view(nth(&d, &dims, i));
            fill_sentinels(&mut src);
            for j in 0..MATRIX {
                let dst_m = nth(&d, &dims, j);
                let prog = CopyProgram::compile(src.mapping(), dst_m.as_ref());
                if !prog.ops().iter().any(|op| matches!(op, CopyOp::StridedRun { .. })) {
                    continue;
                }
                let mut oracle = alloc_view(nth(&d, &dims, j));
                copy_naive(&src, &mut oracle);
                let label = format!(
                    "{} -> {} ({dims:?})",
                    src.mapping().mapping_name(),
                    dst_m.mapping_name()
                );
                for path in available_paths() {
                    let mut got = alloc_view(nth(&d, &dims, j));
                    prog.execute_with_path(&src, &mut got, path);
                    assert_eq!(got.blobs(), oracle.blobs(), "{label} serial {path:?}");
                    let progs = shard_programs(src.mapping(), dst_m.as_ref(), 3);
                    let mut par = alloc_view(nth(&d, &dims, j));
                    execute_parallel_with(&progs, &src, &mut par, path);
                    assert_eq!(par.blobs(), oracle.blobs(), "{label} parallel {path:?}");
                }
            }
        }
    }
}

/// The raw strided-run kernels against a byte-level oracle on random
/// shapes: element sizes around the 4/8-byte gather specializations,
/// counts straddling the vector-width thresholds, strides including
/// dense (`stride == elem`, the contiguous store fast path) and gappy.
#[test]
fn prop_strided_run_raw_matches_bytewise_oracle() {
    use llama::view::simd::strided_run;
    use llama::workloads::rng::SplitMix64;
    for seed in 0..cases() {
        let mut rng = SplitMix64::new(seed ^ 0x51AD);
        let elem = [1usize, 2, 3, 4, 8, 12, 16][rng.below(7)];
        let count = [0usize, 1, 3, 4, 7, 8, 9, 31, 64, 129][rng.below(10)];
        let src_stride = elem + rng.below(9);
        let dst_stride = elem + rng.below(9);
        let src_off = rng.below(5);
        let dst_off = rng.below(5);
        let src_len = src_off + count.saturating_sub(1) * src_stride + elem + rng.below(8);
        let dst_len = dst_off + count.saturating_sub(1) * dst_stride + elem + rng.below(8);
        let src: Vec<u8> = (0..src_len).map(|_| rng.next_u64() as u8).collect();
        let mut expect = vec![0u8; dst_len];
        for k in 0..count {
            let so = src_off + k * src_stride;
            let doff = dst_off + k * dst_stride;
            expect[doff..doff + elem].copy_from_slice(&src[so..so + elem]);
        }
        for path in available_paths() {
            let mut got = vec![0u8; dst_len];
            strided_run(
                path, &src, src_off, src_stride, &mut got, dst_off, dst_stride, elem, count,
            );
            assert_eq!(
                got, expect,
                "seed {seed}: elem {elem} count {count} strides {src_stride}/{dst_stride} {path:?}"
            );
        }
    }
}

/// Batch cursor reads/writes agree with scalar cursor accesses on both
/// cursor shapes — affine (packed AoS) and piecewise (AoSoA-4, where a
/// 8-wide batch crosses two lane blocks) — at random positions
/// including the extent's tail.
#[test]
fn prop_batch_cursors_match_scalar_accesses() {
    use llama::view::simd::{SimdCursorRead, SimdCursorWrite};
    use llama::view::PlanCursorsMut;
    use llama::workloads::rng::SplitMix64;
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(37);

    fn check_view<M: Mapping>(mut v: llama::view::View<M, Vec<u8>>, label: &str) {
        // Finite, distinct per-record floats (sentinel bytes could
        // decode to NaN, which never compares equal).
        let n = v.count();
        for i in 0..n {
            v.set::<f32>(i, 0, 100.0 + i as f32);
        }
        let expected: Vec<f32> = (0..n).map(|i| v.get::<f32>(i, 0)).collect();
        let mut rng = SplitMix64::new(0xBA7C);
        match v.plan_cursors_mut() {
            PlanCursorsMut::Affine(cur) => {
                for _ in 0..64 {
                    let lin = rng.below(n - 7);
                    // SAFETY: lin + 7 < n over a validated view.
                    let got: [f32; 8] = unsafe { cur[0].read_batch(lin) };
                    assert_eq!(&got[..], &expected[lin..lin + 8], "{label} read lin {lin}");
                    // Round-trip: write the batch back shifted, check
                    // scalar reads see it, then restore.
                    let bumped = got.map(|x| x + 1.0);
                    unsafe { cur[0].write_batch(lin, bumped) };
                    for k in 0..8 {
                        let r: f32 = unsafe { cur[0].read_at(lin + k) };
                        assert_eq!(r, expected[lin + k] + 1.0, "{label} write lin {lin}+{k}");
                    }
                    unsafe { cur[0].write_batch(lin, got) };
                }
            }
            PlanCursorsMut::Piecewise(cur) => {
                for _ in 0..64 {
                    let lin = rng.below(n - 7);
                    let got: [f32; 8] = unsafe { cur[0].read_batch(lin) };
                    assert_eq!(&got[..], &expected[lin..lin + 8], "{label} read lin {lin}");
                    let bumped = got.map(|x| x + 1.0);
                    unsafe { cur[0].write_batch(lin, bumped) };
                    for k in 0..8 {
                        let r: f32 = unsafe { cur[0].read_at(lin + k) };
                        assert_eq!(r, expected[lin + k] + 1.0, "{label} write lin {lin}+{k}");
                    }
                    unsafe { cur[0].write_batch(lin, got) };
                }
            }
            PlanCursorsMut::Generic => panic!("{label}: expected a closed-form plan"),
        }
    }

    check_view(alloc_view(AoS::packed(&d, dims.clone())), "affine (AoS packed)");
    // Lane count 4 < batch width 8: every batch crosses lane blocks.
    check_view(alloc_view(AoSoA::new(&d, dims.clone(), 4)), "piecewise (AoSoA-4)");
}

/// Detection sanity shared by benches: the compile-time gate and the
/// runtime path agree, `Scalar` is always available, and the detected
/// path is in the available set.
#[test]
fn detection_is_coherent() {
    use llama::view::simd::{detect, simd_compiled, SimdPath};
    let paths = available_paths();
    assert_eq!(paths.last(), Some(&SimdPath::Scalar));
    assert!(paths.contains(&detect()));
    if !simd_compiled() {
        assert_eq!(paths, vec![SimdPath::Scalar]);
        assert_eq!(detect(), SimdPath::Scalar);
    }
}
