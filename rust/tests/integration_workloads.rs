//! Cross-module integration tests: workloads running over the full
//! mapping/view/copy machinery, including failure injection on the
//! frame store and layout-equivalence sweeps.

use llama::prelude::*;
use llama::workloads::lbm::split4::build_split4;
use llama::workloads::lbm::step as lbm;
use llama::workloads::lbm::{cell_dim, Geometry};
use llama::workloads::nbody::{self, llama_impl};
use llama::workloads::picframe::frames::ParticleStore;
use llama::workloads::picframe::{attr_dim, ParticleAttrs, FRAME_SIZE};

/// The full §4.3 workflow as one integration test: trace -> group ->
/// split -> run -> identical physics, then copy the state to a plain
/// AoS view and verify field-wise equality.
#[test]
fn lbm_trace_split_copy_roundtrip() {
    let geo = Geometry::channel_with_sphere(10, 8, 6, 7);
    let d = cell_dim();

    // Trace one step.
    let traced = Trace::new(AoS::aligned(&d, geo.dims.clone()));
    let mut t_src = alloc_view(traced);
    let mut t_dst = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm::init(&mut t_src, &geo);
    lbm::step(&t_src, &mut t_dst);
    let groups = t_src.mapping().equal_count_groups(4);

    // Run 3 steps under the derived split and under plain AoS.
    let split = build_split4(&d, geo.dims.clone(), &groups);
    let mut s_a = alloc_view(split);
    let mut s_b = alloc_view(build_split4(&d, geo.dims.clone(), &groups));
    let mut a_a = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    let mut a_b = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm::init(&mut s_a, &geo);
    lbm::init(&mut s_b, &geo);
    lbm::init(&mut a_a, &geo);
    lbm::init(&mut a_b, &geo);
    for _ in 0..3 {
        lbm::step(&s_a, &mut s_b);
        std::mem::swap(&mut s_a, &mut s_b);
        lbm::step(&a_a, &mut a_b);
        std::mem::swap(&mut a_a, &mut a_b);
    }
    assert!(views_equal(&s_a, &a_a), "split and AoS runs diverged");

    // And the layout-aware copy out of the split works.
    let mut out = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
    copy(&s_a, &mut out);
    assert!(views_equal(&s_a, &out));
}

/// n-body over a Morton-linearized mapping still matches the manual
/// reference (space-filling curves change only the layout).
#[test]
fn nbody_on_morton_curve_matches() {
    let n = 64;
    let d = nbody::particle_dim();
    let s = nbody::init_particles(n, 3);
    let mut reference = nbody::manual::NBodyAoS::from_state(&s);
    reference.update();
    reference.mv();

    let mapping = AoS::with_linearizer(&d, ArrayDims::linear(n), MortonCurve, true);
    let mut v = alloc_view(mapping);
    llama_impl::load_state(&mut v, &s);
    llama_impl::update(&mut v);
    llama_impl::mv(&mut v);
    assert_eq!(
        nbody::max_rel_error(&reference.to_state(), &llama_impl::store_state(&v)),
        0.0
    );
}

/// Views over external (caller-owned) memory compose with the copy
/// engine — the PIConGPU "reinterpret a plain byte array" use case.
#[test]
fn external_blob_views_roundtrip() {
    use llama::blob::ExternalBytesMut;
    let d = nbody::particle_dim();
    let n = 32;
    let mapping = AoS::packed(&d, ArrayDims::linear(n));
    let total = mapping.blob_size(0);
    let mut backing = vec![0u8; total];
    {
        let m2 = AoS::packed(&d, ArrayDims::linear(n));
        let mut ext = llama::view::View::from_blobs(m2, vec![ExternalBytesMut(&mut backing)]);
        let s = nbody::init_particles(n, 8);
        llama_impl::load_state(&mut ext, &s);
        llama_impl::update(&mut ext);
    }
    // Reinterpret the same bytes with an owning view and check values.
    let owned = llama::view::View::from_blobs(mapping, vec![backing]);
    let out = llama_impl::store_state(&unsafe_as_mut(owned));
    assert!(out.vel.iter().flatten().all(|v| v.is_finite()));
    assert!(out.vel.iter().flatten().any(|v| *v != 0.0));
}

// store_state takes BlobMut views; a Vec<u8>-backed view satisfies it.
fn unsafe_as_mut(
    v: llama::view::View<AoS, Vec<u8>>,
) -> llama::view::View<AoS, Vec<u8>> {
    v
}

/// Failure injection: a frame store survives pathological churn —
/// every particle leaves its cell every step, in both directions.
#[test]
fn picframe_pathological_churn() {
    let d = attr_dim();
    let store_dims = ArrayDims::linear(FRAME_SIZE);
    let mut st = ParticleStore::new(AoSoA::new(&d, store_dims, 16), [2, 2, 2]);
    // Fill cell 0 with particles that all want to leave in different
    // directions.
    for i in 0..(FRAME_SIZE * 3 + 17) {
        let dir = i % 6;
        let mut pos = [0.5f32; 3];
        pos[dir / 2] = if dir % 2 == 0 { 1.5 } else { -0.5 };
        st.push(0, ParticleAttrs { pos, mom: [0.0; 3], weighting: 1.0, cell_idx: i as i32 });
    }
    let total = st.particle_count();
    for _ in 0..4 {
        st.exchange();
        st.check_invariants().unwrap();
        assert_eq!(st.particle_count(), total);
        // Push everyone out again.
        st.drift(5.0);
    }
}

/// Zero-sized and single-record data spaces behave.
#[test]
fn degenerate_extents() {
    let d = nbody::particle_dim();
    for n in [1usize] {
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
        let s = nbody::init_particles(n, 1);
        llama_impl::load_state(&mut v, &s);
        llama_impl::update(&mut v);
        llama_impl::mv(&mut v);
        assert!(llama_impl::store_state(&v).vel[0][0].is_finite());
    }
    // Empty views: allocation + iteration are no-ops, copies succeed.
    let m = AoS::aligned(&d, ArrayDims::linear(0));
    let src = alloc_view(m);
    let mut dst = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(0)));
    copy_naive(&src, &mut dst);
    assert_eq!((&src).into_iter().count(), 0);
}

/// Hilbert-curve layouts behave like any other mapping: round-trip,
/// copy interop and advisor compatibility.
#[test]
fn hilbert_mapped_views_roundtrip_and_copy() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::from([6, 10]);
    let mut hv = alloc_view(AoS::with_linearizer(&d, dims.clone(), HilbertCurve2D, false));
    for a in 0..6 {
        for b in 0..10 {
            hv.set_nd::<f32>(&[a, b], 0, (a * 100 + b) as f32);
        }
    }
    for a in 0..6 {
        for b in 0..10 {
            assert_eq!(hv.get_nd::<f32>(&[a, b], 0), (a * 100 + b) as f32);
        }
    }
    // Field-wise copy out of the curve layout into row-major SoA.
    let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
    copy_naive(&hv, &mut soa);
    assert!(views_equal(&hv, &soa));
    // Packed AoS stays chunk-compatible even under a curve order
    // (1-lane runs resolve each slot through the mapping), and the
    // copy stays correct; curve SoA/AoSoA would fall back field-wise.
    assert_eq!(llama::copy::copy(&hv, &mut soa), llama::copy::CopyMethod::AoSoAChunked);
    assert!(views_equal(&hv, &soa));
    let curve_soa = SoA::with_linearizer(&d, dims.clone(), HilbertCurve2D, true);
    assert!(curve_soa.aosoa_lanes().is_none());
}

/// The advisor's recommendation can be instantiated and run.
#[test]
fn advisor_recommendation_is_actionable() {
    let d = nbody::particle_dim();
    let n = 64;
    let t = Trace::new(AoS::packed(&d, ArrayDims::linear(n)));
    let mut v = alloc_view(t);
    let s = nbody::init_particles(n, 4);
    llama_impl::load_state(&mut v, &s);
    v.mapping().reset();
    llama_impl::mv(&mut v);
    match recommend(v.mapping(), AccessPattern::Streaming) {
        Recommendation::SoaMultiBlob => {
            let mut better = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
            copy_naive(&v, &mut better);
            assert!(views_equal(&v, &better));
        }
        Recommendation::SplitHotCold { hot } => {
            assert!(!hot.is_empty());
        }
        Recommendation::Aos => panic!("streaming 6/7 fields should not advise AoS"),
    }
}

/// The One mapping broadcasts writes — every index reads the last
/// stored record (documented aliasing).
#[test]
fn one_mapping_broadcast_semantics() {
    let d = nbody::particle_dim();
    let mut v = alloc_view(One::new(&d, ArrayDims::linear(100)));
    v.set::<f32>(13, 6, 2.5); // mass at index 13
    for i in 0..100 {
        assert_eq!(v.get::<f32>(i, 6), 2.5);
    }
}
