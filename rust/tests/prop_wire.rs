//! Wire-serialization acceptance properties (EXPERIMENTS.md §Wire):
//! `copy::wire` round trips are bit-identical to the `copy_naive`
//! oracle across the full 13-mapping matrix — including `Byteswap`
//! endpoints in both directions and tail-block extents — affine packs
//! never degrade to the element gather, corrupted or truncated
//! manifests are rejected before the payload is trusted, the pipelined
//! chunked framing mode reassembles byte-identically to the staged
//! frame for every layout in the matrix, `step=` tags ride the
//! manifest grammar untouched, and the framed protocol survives a real
//! process boundary (`llama wire-worker` spoken to over OS pipes).

mod prop_support;

use llama::coordinator::wire_demo::serve_frame;
use llama::prelude::*;
use llama::workloads::nbody;
use llama::workloads::picframe::{attr_dim, FRAME_SIZE};
use prop_support::*;

/// The explicit layout matrix of `prop_copy_matrix` (index 8 is the
/// aliasing `One` mapping).
const MATRIX: usize = 13;
const ONE_IDX: usize = 8;

fn nth(d: &RecordDim, dims: &ArrayDims, k: usize) -> Box<dyn Mapping> {
    match k {
        0 => Box::new(AoS::aligned(d, dims.clone())),
        1 => Box::new(AoS::packed(d, dims.clone())),
        2 => Box::new(SoA::single_blob(d, dims.clone())),
        3 => Box::new(SoA::multi_blob(d, dims.clone())),
        4 => Box::new(AoSoA::new(d, dims.clone(), 2)),
        5 => Box::new(AoSoA::new(d, dims.clone(), 4)),
        6 => Box::new(AoSoA::new(d, dims.clone(), 8)),
        7 => Box::new(AoSoA::new(d, dims.clone(), 16)),
        8 => Box::new(One::new(d, dims.clone())),
        9 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        )),
        10 => Box::new(Split::new(
            d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 8),
        )),
        11 => Box::new(Byteswap::new(AoS::packed(d, dims.clone()))),
        12 => Box::new(Heatmap::with_granularity(AoS::packed(d, dims.clone()), 4)),
        _ => unreachable!("matrix has {MATRIX} entries"),
    }
}

/// Extents with tail blocks at every lane count in the matrix (13 and
/// 97 are prime; 5×7 is multi-dimensional).
fn extents() -> Vec<ArrayDims> {
    vec![ArrayDims::linear(13), ArrayDims::from([5, 7]), ArrayDims::linear(97)]
}

/// The acceptance property: `serialize_endian` → `deserialize_into`
/// restores the exact bytes `copy_naive` would have produced, for
/// every mapping in the matrix, both payload byte orders, every tail
/// extent — and the message is internally consistent along the way.
#[test]
fn prop_wire_round_trip_matches_the_naive_oracle() {
    let d = nbody::particle_dim();
    for dims in extents() {
        for k in 0..MATRIX {
            let mut src = alloc_view(nth(&d, &dims, k));
            fill_sentinels(&mut src);
            let mut oracle = alloc_view(nth(&d, &dims, k));
            copy_naive(&src, &mut oracle);
            for endian in [WireEndian::native(), WireEndian::native().swapped()] {
                let label = format!("{} {endian:?} ({dims:?})", src.mapping().mapping_name());
                let msg = serialize_endian(&src, endian).unwrap();
                assert_eq!(msg.manifest.endian, endian, "{label}");
                assert_eq!(msg.payload_len(), msg.manifest.payload_len(), "{label}");
                // The zero-copy wire view reads the payload in place
                // (through swapping accessors for the foreign order).
                if k != ONE_IDX {
                    assert!(views_equal(&src, &wire_view(&msg).unwrap()), "{label}");
                }
                // The compiled unpack restores the oracle's bytes.
                let mut back = alloc_view(nth(&d, &dims, k));
                deserialize_into(&msg, &mut back).unwrap();
                assert_eq!(back.blobs(), oracle.blobs(), "{label}");
            }
        }
    }
}

/// A framed stream carrying the whole matrix round trips message by
/// message and terminates with a clean EOF.
#[test]
fn prop_framing_round_trips_the_whole_matrix() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(13);
    let mut stream = Vec::new();
    for k in 0..MATRIX {
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        let endian =
            if k % 2 == 0 { WireEndian::native() } else { WireEndian::native().swapped() };
        write_message(&mut stream, &serialize_endian(&src, endian).unwrap()).unwrap();
    }
    let mut r = std::io::Cursor::new(stream);
    for k in 0..MATRIX {
        let msg = read_message(&mut r).unwrap().unwrap_or_else(|| panic!("message {k}"));
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        let mut oracle = alloc_view(nth(&d, &dims, k));
        copy_naive(&src, &mut oracle);
        let mut back = alloc_view(nth(&d, &dims, k));
        deserialize_into(&msg, &mut back).unwrap();
        assert_eq!(back.blobs(), oracle.blobs(), "matrix entry {k}");
    }
    assert!(read_message(&mut r).unwrap().is_none(), "clean EOF");
}

/// Affine sources never pack through the element gather: equal
/// representation stays on the verbatim strategies, mismatched
/// representation compiles swap runs — in both directions.
#[test]
fn wire_packs_never_degrade_affine_layouts_to_gather() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(29);
    let swapped = WireEndian::native().swapped();

    let mut packed = alloc_view(AoS::packed(&d, dims.clone()));
    fill_sentinels(&mut packed);
    let (_, m) = serialize_with(&packed, WireEndian::native(), &VecAlloc).unwrap();
    assert_eq!(m, CopyMethod::Blobwise, "identical pair is one memcpy");
    let (_, m) = serialize_with(&packed, swapped, &VecAlloc).unwrap();
    assert_eq!(m, CopyMethod::SwapProgram, "cross-endian pack swaps, not gathers");

    let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
    fill_sentinels(&mut soa);
    let (_, m) = serialize_with(&soa, swapped, &VecAlloc).unwrap();
    assert_eq!(m, CopyMethod::SwapProgram, "strided cross-endian pack swaps");

    // A byteswapped source sent in its own byte order is equal
    // representation again: verbatim, no per-element work.
    let mut foreign = alloc_view(Byteswap::new(AoS::packed(&d, dims.clone())));
    fill_sentinels(&mut foreign);
    let (_, m) = serialize_with(&foreign, swapped, &VecAlloc).unwrap();
    assert_eq!(m, CopyMethod::Blobwise, "matching representations move verbatim");
    let (_, m) = serialize_with(&foreign, WireEndian::native(), &VecAlloc).unwrap();
    assert_eq!(m, CopyMethod::SwapProgram, "re-nativizing pack swaps");
}

/// Corrupted manifests — unknown layout tokens, tampered blob sizes,
/// broken record grammar, truncation — are rejected by the framed
/// reader before any payload is trusted.
#[test]
fn corrupted_and_truncated_manifests_are_rejected() {
    let d = nbody::particle_dim();
    let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(13)));
    fill_sentinels(&mut src);
    let mut stream = Vec::new();
    write_message(&mut stream, &serialize(&src).unwrap()).unwrap();
    let text = String::from_utf8_lossy(&stream).into_owned();

    // Same-length substitutions keep the header's manifest_len valid,
    // so the failure is the manifest parse itself, not the framing.
    for (from, to) in [
        ("layout=aos:packed", "layout=sos:packed"), // unknown recipe
        ("endian=", "endiam="),                     // missing key
        ("mass:f32", "mass:f33"),                   // broken record grammar
        ("blobs=364", "blobs=363"),                 // tampered blob size (13 × 28 B)
    ] {
        let bad = text.replacen(from, to, 1);
        assert_ne!(bad, text, "substitution {from:?} must apply");
        assert!(
            read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err(),
            "corruption {from:?} -> {to:?} must be rejected"
        );
    }

    // Truncation inside the manifest line hits EOF before a parse.
    let mut cut = stream.clone();
    cut.truncate(30);
    assert!(read_message(&mut std::io::Cursor::new(cut)).is_err());

    // Direct parse: declared blob sizes must match the rebuilt layout.
    assert!(WireManifest::parse_line(
        "wire record={a:f32} dims=4 layout=aos:packed endian=little blobs=17"
    )
    .is_err());
}

/// Flatten any view of `d` × `dims` to packed-AoS record bytes through
/// the `copy_naive` oracle: record `r` occupies bytes
/// `r*packed_size .. (r+1)*packed_size`, so sub-ranges of any layout
/// can be compared byte for byte in one canonical space.
fn packed_bytes<M: Mapping, B: Blob>(v: &View<M, B>, d: &RecordDim) -> Vec<u8> {
    let mut packed = alloc_view(AoS::packed(d, v.mapping().dims().clone()));
    copy_naive(v, &mut packed);
    packed.blobs()[0].clone()
}

/// Range-restricted serialization: `serialize_range_endian` →
/// `deserialize_range_into` restores exactly the records inside the
/// range — bit-identical to the `copy_naive` oracle's sub-range — and
/// leaves every record outside it untouched, for every mapping in the
/// matrix, both byte orders, lane-unaligned boundaries and tail
/// extents included.
#[test]
fn prop_wire_range_round_trips_match_the_naive_sub_range() {
    let d = nbody::particle_dim();
    let rec = d.packed_size();
    for dims in extents() {
        let count = dims.count();
        // Whole view, lane-unaligned interior slabs, and the tail.
        let ranges = [
            (0, count),
            (0, count / 2),
            (1, count - 1),
            (count / 3, 2 * count / 3),
            (count - 3, count),
        ];
        for k in 0..MATRIX {
            let mut src = alloc_view(nth(&d, &dims, k));
            fill_sentinels(&mut src);
            let src_bytes = packed_bytes(&src, &d);
            for &(begin, end) in ranges.iter().filter(|(b, e)| b < e) {
                for endian in [WireEndian::native(), WireEndian::native().swapped()] {
                    let label = format!(
                        "{} {endian:?} {begin}..{end} ({dims:?})",
                        src.mapping().mapping_name()
                    );
                    let msg = serialize_range_endian(&src, begin, end, endian).unwrap();
                    assert_eq!(msg.manifest.range, Some((begin, end)), "{label}");
                    assert_eq!(msg.manifest.payload_records(), end - begin, "{label}");
                    assert_eq!(msg.payload_len(), msg.manifest.payload_len(), "{label}");
                    // The zero-copy wire view reads the slab's native
                    // values in place (swapping accessors for foreign
                    // orders); flattened it must equal the oracle's
                    // packed sub-range.
                    let slab = packed_bytes(&wire_view(&msg).unwrap(), &d);
                    assert_eq!(slab, src_bytes[begin * rec..end * rec], "{label} wire view");
                    // The compiled unpack restores the range into a
                    // zeroed twin and touches nothing else.
                    let mut back = alloc_view(nth(&d, &dims, k));
                    deserialize_range_into(&msg, &mut back).unwrap();
                    let back_bytes = packed_bytes(&back, &d);
                    assert_eq!(
                        back_bytes[begin * rec..end * rec],
                        src_bytes[begin * rec..end * rec],
                        "{label} in-range records"
                    );
                    if k != ONE_IDX {
                        // One aliases every record onto the same bytes,
                        // so only it may observe writes outside the
                        // range; everywhere else the zeros survive.
                        assert!(
                            back_bytes[..begin * rec].iter().all(|&b| b == 0)
                                && back_bytes[end * rec..].iter().all(|&b| b == 0),
                            "{label} out-of-range records must stay untouched"
                        );
                    }
                }
            }
        }
    }
}

/// `serialize_sharded` tiles the record space in order at the source
/// plan's shard alignment, and `deserialize_sharded_into` reassembles
/// the shards — arriving in any order — back to the `copy_naive`
/// oracle's bytes.
#[test]
fn sharded_messages_tile_the_view_and_reassemble_bit_identically() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(97);
    for k in [1usize, 3, 6, 9] {
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        let mut oracle = alloc_view(nth(&d, &dims, k));
        copy_naive(&src, &mut oracle);
        let mut msgs = serialize_sharded(&src, WireEndian::native().swapped(), 4).unwrap();
        assert!(!msgs.is_empty() && msgs.len() <= 4, "matrix entry {k}");
        let align = shard_align(&src.mapping().plan());
        let mut covered = 0usize;
        for m in &msgs {
            let (b, e) = m.manifest.range.expect("shards carry ranges");
            assert_eq!(b, covered, "matrix entry {k}: shards tile in order");
            assert!(e == 97 || e % align == 0, "matrix entry {k}: boundary {e} off {align}");
            covered = e;
        }
        assert_eq!(covered, 97, "matrix entry {k}");
        msgs.reverse(); // reassembly must not depend on arrival order
        let mut back = alloc_view(nth(&d, &dims, k));
        deserialize_sharded_into(&msgs, &mut back).unwrap();
        assert_eq!(back.blobs(), oracle.blobs(), "matrix entry {k}");
        // Partial deliveries are rejected before any byte lands.
        let mut partial = alloc_view(nth(&d, &dims, k));
        assert!(deserialize_sharded_into(&msgs[1..], &mut partial).is_err());
    }
}

/// The pipelined chunked framing mode against the staged oracle,
/// across the full layout matrix: `write_range_chunked` streams the
/// pack chunk by chunk, yet the reassembled message must equal the
/// staged `serialize_range_endian` frame bit for bit — manifest, step
/// tag, and payload — for every mapping, both byte orders, and chunk
/// sizes from degenerate (1 record) past the whole range.
#[test]
fn prop_chunked_framing_matches_the_staged_frame_across_the_matrix() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(97);
    let (begin, end) = (3usize, 90);
    for k in 0..MATRIX {
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        for endian in [WireEndian::native(), WireEndian::native().swapped()] {
            for chunk in [1usize, 7, 32, 200] {
                let label = format!("matrix entry {k} {endian:?} chunk={chunk}");
                let mut stream = Vec::new();
                let (_, chunks) =
                    write_range_chunked(&mut stream, &src, begin, end, endian, Some(k), chunk)
                        .unwrap();
                assert!(chunks >= 1, "{label}");
                assert_eq!(chunks == 1, chunk >= end - begin, "{label}: chunk count");
                // The stream is in chunked mode, not the staged frame.
                let header_end = stream.iter().position(|&b| b == b'\n').unwrap();
                let header = std::str::from_utf8(&stream[..header_end]).unwrap();
                assert!(header.ends_with(" chunked"), "{label}: header {header:?}");
                let mut r = std::io::Cursor::new(stream);
                let got = read_message(&mut r).unwrap().expect("chunked frame");
                assert!(read_message(&mut r).unwrap().is_none(), "{label}: clean EOF");
                let mut want = serialize_range_endian(&src, begin, end, endian).unwrap();
                want.manifest.step = Some(k);
                assert_eq!(got, want, "{label}");
            }
        }
    }
}

/// `step=` is a pure addressing tag: it survives framing in both modes,
/// never perturbs the payload, and its absence round trips as absence.
#[test]
fn step_tags_ride_the_frame_untouched_in_both_modes() {
    let d = nbody::particle_dim();
    let mut src = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(41)));
    fill_sentinels(&mut src);

    // Staged mode: tag the manifest by hand.
    let mut tagged = serialize_range(&src, 5, 29).unwrap();
    tagged.manifest.step = Some(usize::MAX);
    let untagged = serialize_range(&src, 5, 29).unwrap();
    assert_eq!(tagged.payload, untagged.payload, "the tag never touches the payload");
    let mut stream = Vec::new();
    write_message(&mut stream, &tagged).unwrap();
    write_message(&mut stream, &untagged).unwrap();
    let mut r = std::io::Cursor::new(stream);
    let back = read_message(&mut r).unwrap().expect("tagged frame");
    assert_eq!(back.manifest.step, Some(usize::MAX), "extreme tag survives the grammar");
    assert_eq!(back, tagged);
    let back = read_message(&mut r).unwrap().expect("untagged frame");
    assert_eq!(back.manifest.step, None, "absence round trips as absence");

    // Chunked mode: `None` stays `None` on the reassembled message.
    let mut stream = Vec::new();
    write_range_chunked(&mut stream, &src, 0, 41, WireEndian::native(), None, 8).unwrap();
    let got = read_message(&mut std::io::Cursor::new(stream)).unwrap().expect("frame");
    assert_eq!(got.manifest.step, None);
}

/// Range packs inherit the full-view strategy guarantee: strategy
/// selection is plan-based, so closed-form layouts stay on chunked,
/// strided, or swap runs at *every* slab boundary — lane-aligned or
/// not — and only the generic plans (`One`, `Heatmap`) take the
/// documented element-gather fallback.
#[test]
fn range_packs_on_closed_form_layouts_never_degrade_to_gather() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(96);
    let swapped = WireEndian::native().swapped();
    // (0,32)/(16,80)/(64,96) are multiples of every lane count in the
    // matrix; (3,21) and (95,96) are aligned to none of them.
    let boundaries = [(0usize, 32usize), (16, 80), (3, 21), (64, 96), (95, 96)];
    for k in (0..MATRIX).filter(|&k| k != ONE_IDX && k != 12) {
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        for &(b, e) in &boundaries {
            for endian in [WireEndian::native(), swapped] {
                let (_, m) = serialize_range_with(&src, b, e, endian, &VecAlloc).unwrap();
                assert_ne!(
                    m,
                    CopyMethod::FieldWise,
                    "matrix entry {k} range {b}..{e} ({endian:?}) must not gather"
                );
            }
        }
    }
    // The aliasing and counting wrappers are generic plans: the
    // element gather is their legal (and only) pack strategy.
    for k in [ONE_IDX, 12] {
        let mut src = alloc_view(nth(&d, &dims, k));
        fill_sentinels(&mut src);
        let (_, m) = serialize_range_with(&src, 16, 48, WireEndian::native(), &VecAlloc).unwrap();
        assert_eq!(m, CopyMethod::FieldWise, "matrix entry {k} packs element-wise");
    }
}

/// Out-of-bounds or inverted ranges are rejected at serialization
/// time, and range messages refuse full-view deserialization entry
/// points (and vice versa).
#[test]
fn range_bounds_and_entry_points_are_enforced() {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(13);
    let mut src = alloc_view(AoS::packed(&d, dims.clone()));
    fill_sentinels(&mut src);
    assert!(serialize_range(&src, 5, 4).is_err(), "inverted range");
    assert!(serialize_range(&src, 0, 14).is_err(), "end past the extent");
    assert!(serialize_range(&src, 3, 3).is_err(), "empty range");

    let ranged = serialize_range(&src, 2, 9).unwrap();
    let whole = serialize(&src).unwrap();
    let mut dst = alloc_view(AoS::packed(&d, dims.clone()));
    assert!(
        deserialize_range_into(&whole, &mut dst).is_err(),
        "whole-view messages carry no range="
    );
    let mut short = alloc_view(AoS::packed(&d, ArrayDims::linear(7)));
    assert!(
        deserialize_range_into(&ranged, &mut short).is_err(),
        "range landing needs the manifest's full data space"
    );
    // ..._at ignores the manifest's origin: the 7-record slab fits the
    // 7-record view at offset 0 even though it came from records 2..9.
    deserialize_range_into_at(&ranged, &mut short, 0).unwrap();
    let src_bytes = packed_bytes(&src, &d);
    assert_eq!(packed_bytes(&short, &d), src_bytes[2 * d.packed_size()..9 * d.packed_size()]);
    assert!(
        deserialize_range_into_at(&ranged, &mut short, 1).is_err(),
        "slab past the destination's end"
    );
}

/// The framed protocol across a real process boundary: spawn the
/// `llama wire-worker` binary and speak the request/response protocol
/// over its pipes, alternating byte orders. The worker's response must
/// be byte-identical to running its step (`serve_frame`) locally.
#[test]
fn wire_worker_process_round_trips_frames() {
    use std::io::BufReader;
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_llama"))
        .arg("wire-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn llama wire-worker");
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());

    let d = attr_dim();
    let dims = ArrayDims::linear(FRAME_SIZE);
    for f in 0..4u64 {
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_sentinels(&mut frame);
        let endian =
            if f % 2 == 0 { WireEndian::native() } else { WireEndian::native().swapped() };
        let request = serialize_endian(&frame, endian).unwrap();
        write_message(&mut stdin, &request).unwrap();
        let response = read_message(&mut stdout).unwrap().expect("worker response");
        assert_eq!(response, serve_frame(&request).unwrap(), "frame {f} ({endian:?})");
    }
    drop(stdin); // EOF = shutdown
    let status = child.wait().unwrap();
    assert!(status.success(), "worker exited with {status}");
}

/// The `llama wire` demo command end to end: parent + worker processes,
/// verified frame exchange, zero exit code.
#[test]
fn wire_demo_command_verifies_its_exchange() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_llama"))
        .args(["wire", "--quick", "--n", "4"])
        .output()
        .expect("run llama wire");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "llama wire failed: {stdout}\n{stderr}");
    assert!(stdout.contains("round trips verified"), "{stdout}");
    assert!(stdout.contains("cross-endian frames"), "{stdout}");
}
