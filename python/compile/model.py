"""L2: the n-body compute graph in JAX, calling the L1 Pallas kernels.

This is the "model" layer of the three-layer stack: it composes the
Pallas update/move kernels into whole timesteps and multi-step scans,
and is what `aot.py` lowers to the HLO artifacts the Rust runtime
executes. Python never runs at request time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import nbody_pallas as k


def step_soa(x, y, z, vx, vy, vz, m, *, tile=256):
    """One full timestep over SoA state: Pallas update then Pallas move.

    Returns the new (x, y, z, vx, vy, vz, m) tuple.
    """
    vx, vy, vz = k.update_soa(x, y, z, vx, vy, vz, m, tile=tile)
    # Reuse the update tile for the move so the whole step shares one
    # blocking scheme.
    x, y, z = k.move_soa(x, y, z, vx, vy, vz, tile=tile)
    return x, y, z, vx, vy, vz, m


def step_aos(p, *, tile=256):
    """One full timestep over the packed (N, 7) AoS matrix."""
    p = k.update_aos(p, tile=tile)
    return k.move_aos(p, tile=tile)


def steps_soa(x, y, z, vx, vy, vz, m, *, steps, tile=256):
    """`steps` timesteps via lax.scan (single fused executable; the
    scan carry keeps state on-device between iterations)."""

    def body(carry, _):
        return step_soa(*carry, tile=tile), None

    carry, _ = jax.lax.scan(body, (x, y, z, vx, vy, vz, m), None, length=steps)
    return carry


def kinetic_energy_soa(vx, vy, vz, m):
    """Diagnostic reduced on-device and returned as a scalar."""
    return 0.5 * jnp.sum(m * (vx * vx + vy * vy + vz * vz))


def step_soa_with_energy(x, y, z, vx, vy, vz, m, *, tile=256):
    """Timestep + energy diagnostic, the artifact the e2e driver runs."""
    x, y, z, vx, vy, vz, m = step_soa(x, y, z, vx, vy, vz, m, tile=tile)
    return x, y, z, vx, vy, vz, m, kinetic_energy_soa(vx, vy, vz, m)
