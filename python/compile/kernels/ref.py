"""Pure-jnp correctness oracle for the n-body kernels (L1 reference).

Replicates the paper's listing-9 semantics *exactly* (including the
component-wise squared "dist" that feeds the velocity update), so the
Pallas kernels, this oracle, and the Rust `workloads::nbody` kernels all
compute the same function.
"""

import jax.numpy as jnp

TIMESTEP = 0.0001
EPS2 = 0.01


def update_soa(x, y, z, vx, vy, vz, m):
    """All-pairs velocity update on SoA arrays (each shape (N,)).

    Returns updated (vx, vy, vz).
    """
    dx = (x[:, None] - x[None, :]) ** 2
    dy = (y[:, None] - y[None, :]) ** 2
    dz = (z[:, None] - z[None, :]) ** 2
    dist_sqr = EPS2 + dx + dy + dz
    dist_sixth = dist_sqr * dist_sqr * dist_sqr
    inv_dist_cube = 1.0 / jnp.sqrt(dist_sixth)
    sts = m[None, :] * inv_dist_cube * TIMESTEP  # (N, N)
    return (
        vx + jnp.sum(dx * sts, axis=1),
        vy + jnp.sum(dy * sts, axis=1),
        vz + jnp.sum(dz * sts, axis=1),
    )


def update_aos(p):
    """All-pairs velocity update on a packed AoS matrix (N, 7):
    columns = [pos.x, pos.y, pos.z, vel.x, vel.y, vel.z, mass].

    Returns the updated (N, 7) matrix.
    """
    x, y, z = p[:, 0], p[:, 1], p[:, 2]
    vx, vy, vz = p[:, 3], p[:, 4], p[:, 5]
    m = p[:, 6]
    nvx, nvy, nvz = update_soa(x, y, z, vx, vy, vz, m)
    return jnp.stack([x, y, z, nvx, nvy, nvz, m], axis=1)


def move_soa(x, y, z, vx, vy, vz):
    """Position update on SoA arrays; returns (x, y, z)."""
    return (x + vx * TIMESTEP, y + vy * TIMESTEP, z + vz * TIMESTEP)


def move_aos(p):
    """Position update on the packed AoS matrix; returns (N, 7)."""
    return p.at[:, 0:3].add(p[:, 3:6] * TIMESTEP)


def step_soa(x, y, z, vx, vy, vz, m):
    """One full timestep (update then move) on SoA arrays."""
    vx, vy, vz = update_soa(x, y, z, vx, vy, vz, m)
    x, y, z = move_soa(x, y, z, vx, vy, vz)
    return x, y, z, vx, vy, vz, m
