"""L1: the n-body hot spot as Pallas kernels (paper fig 6, adapted to
TPU per DESIGN.md §Hardware-Adaptation).

The paper's CUDA kernels tile 512 particles into *shared memory* per
thread block. The TPU translation: the i-tile of particles is a
BlockSpec-mapped VMEM block, and the j-loop stages `tile`-sized slices
of the position/mass arrays into VMEM via `pl.load` — BlockSpec + the
staged loads express the HBM->VMEM schedule the paper wrote with
threadblocks. The global-memory layout axis of fig 6 becomes the input
representation: SoA (seven (N,) arrays) vs AoS (one packed (N, 7)
matrix, where per-field access is a strided column slice).

Kernels MUST run with interpret=True here: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TIMESTEP = 0.0001
EPS2 = 0.01


def _accum_tile(xi, yi, zi, xj, yj, zj, mj, acc):
    """Listing-9 pairwise interaction for an (I, J) tile pair."""
    ax, ay, az = acc
    dx = (xi[:, None] - xj[None, :]) ** 2
    dy = (yi[:, None] - yj[None, :]) ** 2
    dz = (zi[:, None] - zj[None, :]) ** 2
    dist_sqr = EPS2 + dx + dy + dz
    dist_sixth = dist_sqr * dist_sqr * dist_sqr
    inv_dist_cube = 1.0 / jnp.sqrt(dist_sixth)
    sts = mj[None, :] * inv_dist_cube * TIMESTEP
    return (
        ax + jnp.sum(dx * sts, axis=1),
        ay + jnp.sum(dy * sts, axis=1),
        az + jnp.sum(dz * sts, axis=1),
    )


def _update_soa_kernel(n, tile, xi_ref, yi_ref, zi_ref, vxi_ref, vyi_ref, vzi_ref,
                       xj_ref, yj_ref, zj_ref, mj_ref, ox_ref, oy_ref, oz_ref):
    xi, yi, zi = xi_ref[...], yi_ref[...], zi_ref[...]
    zero = jnp.zeros((tile,), xi.dtype)

    def body(jt, acc):
        sl = (pl.ds(jt * tile, tile),)
        # VMEM staging of the j-tile (the CUDA shared-memory cache).
        xj = pl.load(xj_ref, sl)
        yj = pl.load(yj_ref, sl)
        zj = pl.load(zj_ref, sl)
        mj = pl.load(mj_ref, sl)
        return _accum_tile(xi, yi, zi, xj, yj, zj, mj, acc)

    ax, ay, az = jax.lax.fori_loop(0, n // tile, body, (zero, zero, zero))
    ox_ref[...] = vxi_ref[...] + ax
    oy_ref[...] = vyi_ref[...] + ay
    oz_ref[...] = vzi_ref[...] + az


def update_soa(x, y, z, vx, vy, vz, m, *, tile=256):
    """Velocity update over SoA inputs; returns (vx, vy, vz)."""
    n = x.shape[0]
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    dt = x.dtype
    itile = pl.BlockSpec((tile,), lambda i: (i,))
    full = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_update_soa_kernel, n, tile),
        grid=(n // tile,),
        in_specs=[itile] * 6 + [full] * 4,
        out_specs=[itile] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), dt)] * 3,
        interpret=True,
    )(x, y, z, vx, vy, vz, x, y, z, m)


def _update_aos_kernel(n, tile, pi_ref, pj_ref, out_ref):
    pi = pi_ref[...]  # (tile, 7)
    # Column slices of the packed block: strided "global layout" access.
    xi, yi, zi = pi[:, 0], pi[:, 1], pi[:, 2]
    zero = jnp.zeros((tile,), pi.dtype)

    def body(jt, acc):
        pj = pl.load(pj_ref, (pl.ds(jt * tile, tile), pl.ds(0, 7)))
        return _accum_tile(xi, yi, zi, pj[:, 0], pj[:, 1], pj[:, 2], pj[:, 6], acc)

    ax, ay, az = jax.lax.fori_loop(0, n // tile, body, (zero, zero, zero))
    vel = pi[:, 3:6] + jnp.stack([ax, ay, az], axis=1)
    out_ref[...] = jnp.concatenate([pi[:, 0:3], vel, pi[:, 6:7]], axis=1)


def update_aos(p, *, tile=256):
    """Velocity update over a packed (N, 7) AoS matrix; returns (N, 7)."""
    n = p.shape[0]
    assert p.shape[1] == 7
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    itile = pl.BlockSpec((tile, 7), lambda i: (i, 0))
    full = pl.BlockSpec((n, 7), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_update_aos_kernel, n, tile),
        grid=(n // tile,),
        in_specs=[itile, full],
        out_specs=itile,
        out_shape=jax.ShapeDtypeStruct((n, 7), p.dtype),
        interpret=True,
    )(p, p)


def _move_soa_kernel(x_ref, y_ref, z_ref, vx_ref, vy_ref, vz_ref,
                     ox_ref, oy_ref, oz_ref):
    ox_ref[...] = x_ref[...] + vx_ref[...] * TIMESTEP
    oy_ref[...] = y_ref[...] + vy_ref[...] * TIMESTEP
    oz_ref[...] = z_ref[...] + vz_ref[...] * TIMESTEP


def move_soa(x, y, z, vx, vy, vz, *, tile=1024):
    """Position update over SoA inputs; returns (x, y, z)."""
    n = x.shape[0]
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    itile = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        _move_soa_kernel,
        grid=(n // tile,),
        in_specs=[itile] * 6,
        out_specs=[itile] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), x.dtype)] * 3,
        interpret=True,
    )(x, y, z, vx, vy, vz)


def _move_aos_kernel(p_ref, out_ref):
    p = p_ref[...]
    pos = p[:, 0:3] + p[:, 3:6] * TIMESTEP
    out_ref[...] = jnp.concatenate([pos, p[:, 3:7]], axis=1)


def move_aos(p, *, tile=1024):
    """Position update over the packed AoS matrix; returns (N, 7)."""
    n = p.shape[0]
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    itile = pl.BlockSpec((tile, 7), lambda i: (i, 0))
    return pl.pallas_call(
        _move_aos_kernel,
        grid=(n // tile,),
        in_specs=[itile],
        out_specs=itile,
        out_shape=jax.ShapeDtypeStruct((n, 7), p.dtype),
        interpret=True,
    )(p)
