"""AOT compile path: lower the L2 JAX functions (wrapping the L1 Pallas
kernels) to HLO *text* artifacts the Rust PJRT runtime loads.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which xla_extension 0.5.1 (behind the `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts]
Writes one .hlo.txt per variant plus a whitespace manifest
(`manifest.txt`: name file n tile dtype inputs outputs) the Rust side
parses.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def soa_spec(n, dtype):
    s = jax.ShapeDtypeStruct((n,), dtype)
    return [s] * 7


def aos_spec(n, dtype):
    return [jax.ShapeDtypeStruct((n, 7), dtype)]


# name -> (function, spec builder, n, tile, n_outputs)
def variants(n_update, n_move, tile, steps):
    f32 = jnp.float32
    return {
        # fig 6 "update" row: tiled Pallas kernels, SoA vs AoS global layout.
        "nbody_update_soa": (
            lambda x, y, z, vx, vy, vz, m: model.k.update_soa(
                x, y, z, vx, vy, vz, m, tile=tile
            ),
            soa_spec(n_update, f32), n_update, tile, 3,
        ),
        "nbody_update_aos": (
            lambda p: model.k.update_aos(p, tile=tile),
            aos_spec(n_update, f32), n_update, tile, 1,
        ),
        # fig 6 "no shared memory" reference: direct jnp lowering (XLA
        # fuses, no explicit staging).
        "nbody_update_soa_notile": (
            ref.update_soa, soa_spec(n_update, f32), n_update, 0, 3,
        ),
        # fig 6 "move" row (6 inputs: move does not read mass, and jax
        # prunes unused params from the lowered module).
        "nbody_move_soa": (
            lambda x, y, z, vx, vy, vz: model.k.move_soa(
                x, y, z, vx, vy, vz, tile=tile
            ),
            soa_spec(n_move, f32)[:6], n_move, tile, 3,
        ),
        "nbody_move_aos": (
            lambda p: model.k.move_aos(p, tile=tile),
            aos_spec(n_move, f32), n_move, tile, 1,
        ),
        # e2e driver artifact: full step + energy diagnostic.
        "nbody_step_soa": (
            lambda *a: model.step_soa_with_energy(*a, tile=tile),
            soa_spec(n_update, f32), n_update, tile, 8,
        ),
        # multi-step scan (donate the state: in-place buffer reuse).
        "nbody_steps_soa": (
            functools.partial(_steps, steps=steps, tile=tile),
            soa_spec(n_update, f32), n_update, tile, 7,
        ),
    }


def _steps(x, y, z, vx, vy, vz, m, *, steps, tile):
    return model.steps_soa(x, y, z, vx, vy, vz, m, steps=steps, tile=tile)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--n-update", type=int, default=1024)
    ap.add_argument("--n-move", type=int, default=65536)
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--steps", type=int, default=10)
    # Back-compat with the Makefile's `--out` single-target form.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, (fn, spec, n, tile, n_out) in variants(
        args.n_update, args.n_move, args.tile, args.steps
    ).items():
        jitted = jax.jit(fn)
        lowered = jitted.lower(*spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        layout = "aos" if "_aos" in name else "soa"
        manifest.append(
            f"{name} {name}.hlo.txt n={n} tile={tile} dtype=f32 "
            f"layout={layout} inputs={len(spec)} outputs={n_out}"
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
