"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, tiles, dtypes and value ranges — the paper's
zero-overhead claim is only meaningful if the abstracted kernel is
*exactly* the same function as the reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nbody_pallas as k
from compile.kernels import ref


def make_state(n, dtype, seed):
    rng = np.random.default_rng(seed)
    cols = [
        rng.uniform(-1, 1, n),  # x
        rng.uniform(-1, 1, n),  # y
        rng.uniform(-1, 1, n),  # z
        rng.uniform(-0.01, 0.01, n),  # vx
        rng.uniform(-0.01, 0.01, n),  # vy
        rng.uniform(-0.01, 0.01, n),  # vz
        rng.uniform(0.5, 1.5, n),  # m
    ]
    return [jnp.asarray(c, dtype) for c in cols]


def tol(dtype):
    return dict(rtol=3e-2, atol=3e-3) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-6)


def allclose(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64), **tol(dtype)
    )


# --- hypothesis sweeps -------------------------------------------------

shape_strategy = st.sampled_from([(64, 16), (128, 32), (128, 64), (256, 64), (192, 64)])
dtype_strategy = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, dtype=dtype_strategy, seed=st.integers(0, 2**16))
def test_update_soa_matches_ref(shape, dtype, seed):
    n, tile = shape
    x, y, z, vx, vy, vz, m = make_state(n, dtype, seed)
    got = k.update_soa(x, y, z, vx, vy, vz, m, tile=tile)
    want = ref.update_soa(x, y, z, vx, vy, vz, m)
    for g, w in zip(got, want):
        allclose(g, w, dtype)


@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, dtype=dtype_strategy, seed=st.integers(0, 2**16))
def test_update_aos_matches_ref(shape, dtype, seed):
    n, tile = shape
    p = jnp.stack(make_state(n, dtype, seed), axis=1)
    got = k.update_aos(p, tile=tile)
    want = ref.update_aos(p)
    allclose(got, want, dtype)


@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, dtype=dtype_strategy, seed=st.integers(0, 2**16))
def test_move_matches_ref(shape, dtype, seed):
    n, tile = shape
    x, y, z, vx, vy, vz, _ = make_state(n, dtype, seed)
    got = k.move_soa(x, y, z, vx, vy, vz, tile=tile)
    want = ref.move_soa(x, y, z, vx, vy, vz)
    for g, w in zip(got, want):
        allclose(g, w, dtype)
    p = jnp.stack(make_state(n, dtype, seed), axis=1)
    allclose(k.move_aos(p, tile=tile), ref.move_aos(p), dtype)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_soa_and_aos_kernels_agree(seed):
    """The two global layouts are the same function (fig 6 axis)."""
    n, tile = 128, 32
    state = make_state(n, jnp.float32, seed)
    got_soa = k.update_soa(*state, tile=tile)
    p = jnp.stack(state, axis=1)
    got_aos = k.update_aos(p, tile=tile)
    for d, g in enumerate(got_soa):
        allclose(got_aos[:, 3 + d], g, jnp.float32)


# --- directed cases ----------------------------------------------------

def test_update_is_tile_invariant():
    state = make_state(256, jnp.float32, 3)
    a = k.update_soa(*state, tile=32)
    b = k.update_soa(*state, tile=256)
    # Different tiles reorder the f32 accumulation; values agree to
    # accumulation tolerance, not bit-exactly.
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=3e-5, atol=3e-6)


def test_rejects_non_divisible_tile():
    state = make_state(100, jnp.float32, 0)
    with pytest.raises(AssertionError, match="multiple of tile"):
        k.update_soa(*state, tile=64)


def test_self_interaction_is_finite():
    # All particles at the same point: EPS2 keeps it finite.
    n = 64
    zeros = jnp.zeros((n,), jnp.float32)
    ones = jnp.ones((n,), jnp.float32)
    vx, vy, vz = k.update_soa(zeros, zeros, zeros, zeros, zeros, zeros, ones, tile=32)
    assert np.isfinite(np.asarray(vx)).all()
    np.testing.assert_allclose(vx, 0.0)  # dist == 0 -> no velocity change


def test_velocity_update_matches_rust_constants():
    # One pair; hand-computed from listing 9 (same constants as the
    # Rust workloads::nbody::pp_interaction test).
    x = jnp.asarray([1.0, 0.0], jnp.float32)
    zeros = jnp.zeros((2,), jnp.float32)
    m = jnp.ones((2,), jnp.float32)
    vx, vy, vz = k.update_soa(x, zeros, zeros, zeros, zeros, zeros, m, tile=2)
    # dx²=1, distSqr=1.01, inv=1/1.01^1.5, sts=1e-4*inv; plus the
    # self-pair at dist 0 contributing dx=0.
    expect = 1.0 * (1.0 / (1.01 ** 1.5)) * 1e-4
    np.testing.assert_allclose(vx[0], expect, rtol=1e-5)
    np.testing.assert_allclose(vy, 0.0)
