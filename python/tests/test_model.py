"""L2 correctness: composed steps and scans vs the oracle."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref
from tests.test_kernel import make_state


def test_step_soa_matches_oracle():
    state = make_state(128, jnp.float32, 1)
    got = model.step_soa(*state, tile=32)
    want = ref.step_soa(*state)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=3e-5, atol=3e-6)


def test_step_aos_matches_soa():
    state = make_state(128, jnp.float32, 2)
    soa = model.step_soa(*state, tile=32)
    aos = model.step_aos(jnp.stack(state, axis=1), tile=32)
    np.testing.assert_allclose(aos, jnp.stack(soa, axis=1), rtol=3e-5, atol=3e-6)


def test_scan_equals_loop():
    state = make_state(64, jnp.float32, 3)
    scanned = model.steps_soa(*state, steps=4, tile=32)
    looped = state
    for _ in range(4):
        looped = model.step_soa(*looped, tile=32)
    for s, l in zip(scanned, looped):
        np.testing.assert_allclose(s, l, rtol=1e-6)


def test_energy_diagnostic():
    state = make_state(64, jnp.float32, 4)
    *_, e = model.step_soa_with_energy(*state, tile=32)
    assert e > 0
    vx, vy, vz, m = state[3], state[4], state[5], state[6]
    # Energy grows only a little in one tiny timestep.
    e0 = model.kinetic_energy_soa(vx, vy, vz, m)
    assert abs(float(e) - float(e0)) / float(e0) < 0.5
