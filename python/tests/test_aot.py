"""AOT pipeline: lowering produces loadable HLO text with the expected
entry signature, and the notile SoA artifact contains no transposes of
the big arrays (the L2 perf requirement from DESIGN.md §9)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_roundtrips():
    fn = lambda x: (x * 2.0 + 1.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((8,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8]" in text


def test_update_soa_lowering_shapes():
    spec = [jax.ShapeDtypeStruct((256,), jnp.float32)] * 7
    lowered = jax.jit(
        lambda *a: model.k.update_soa(*a[:6], a[6], tile=64)
    ).lower(*spec)
    text = aot.to_hlo_text(lowered)
    assert text.count("f32[256]") >= 7  # params + outputs


def test_soa_artifact_has_no_transpose():
    spec = [jax.ShapeDtypeStruct((256,), jnp.float32)] * 7
    lowered = jax.jit(
        lambda *a: model.k.update_soa(*a[:6], a[6], tile=64)
    ).lower(*spec)
    text = aot.to_hlo_text(lowered)
    for line in text.splitlines():
        assert "transpose(" not in line, f"unexpected transpose: {line}"


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--n-update", "128", "--n-move", "256", "--tile", "64", "--steps", "2"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 7
    for line in manifest:
        name, fname, *kv = line.split()
        assert (out / fname).exists()
        assert any(k.startswith("layout=") for k in kv)
        head = (out / fname).read_text(encoding="utf-8")[:200]
        assert "HloModule" in head
